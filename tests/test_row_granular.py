"""Row-granular differential checkpointing tests.

Covers the refactor's acceptance criteria:
  * ``PatchSet`` is a validated span container (overlap/bounds
    rejection, legacy-dict coercion, subset/tree round-trips) and
    ``merge_span_chain`` merges chains newest-wins without
    materializing full leaves
  * ``patch_frame`` pwrites row ranges in place at
    ``leaf_offset + row_start * row_stride`` and recomputes partial-leaf
    sha256s over patched + retained bytes
  * a row-mode ``_NumpyAdam`` over real MoE configs persists only the
    routed experts' row extents; the per-row ``--persist-threshold``
    defers individual rows
  * row-granular chains recover bit-identical to full-leaf mode across
    all five backends (local / sharded / memory / remote / peer),
    including restart-resume after crash injection at every
    range-patch and range-fold boundary
  * thousands of tiny patches fold with bounded progress-journal
    growth and without full-leaf materialization
  * every ``StorageBackend.patch`` implementation shares the ABC
    signature; the adaptive fold trigger fires on chain-read
    amplification
"""
import inspect
import os
import time

import numpy as np
import pytest

from repro.checkpoint import StoreConfig, make_store
from repro.checkpoint import io as cio
from repro.checkpoint.backends import (LocalFSBackend, MemoryTierBackend,
                                       ShardedBackend, StorageBackend,
                                       split_sizes)
from repro.checkpoint.patchset import (PatchSet, RowUpdate, Span,
                                       mask_to_intervals, merge_span_chain,
                                       row_update_from_spans)
from repro.checkpoint.peer import PeerReplicaBackend
from repro.checkpoint.remote import FakeObjectStore, RemoteObjectBackend
from repro.checkpoint.store import (CheckpointStore, merge_updates,
                                    walk_leaves)
from repro.configs import get_config
from repro.core.lowdiff_plus import _NumpyAdam, fold_due
from repro.maintenance import InjectedCrash, MaintenanceService

RNG = np.random.default_rng(11)


def rand(shape, scale=1.0, rng=None):
    return (scale * (rng or RNG).standard_normal(shape)).astype(np.float32)


def deep_copy_state(state):
    return {k: ({kk: np.array(vv) for kk, vv in v.items()}
                if isinstance(v, dict) else np.array(v))
            for k, v in state.items()}


def assert_state_equal(a, b, context=""):
    bleaves = dict(walk_leaves(b))
    for path, leaf in walk_leaves(a):
        np.testing.assert_array_equal(
            np.asarray(leaf), np.asarray(bleaves[path]),
            err_msg=f"{context}: leaf {path}")


# --------------------------------------------------------------------------
# PatchSet: validation, coercion, round-trips
# --------------------------------------------------------------------------

def test_patchset_coerces_legacy_whole_leaf_dicts():
    arr = rand((8, 4))
    ps = PatchSet.coerce({"a0": arr})
    assert ps.names() == ["a0"]
    assert ps.is_whole("a0")
    assert ps.shape_of("a0") == (8, 4)
    assert ps.nbytes == arr.nbytes
    # idempotent on an existing PatchSet
    assert PatchSet.coerce(ps) is ps
    # RowUpdate values coerce into their spans
    ru = row_update_from_spans([Span(2, rand((2, 4)))], (8, 4))
    ps2 = PatchSet.coerce({"a0": ru})
    assert ps2.extents() == {"a0": [[2, 4]]}
    assert not ps2.is_whole("a0")


def test_patchset_rejects_overlap_bounds_and_tail_mismatch():
    ps = PatchSet()
    ps.add("a0", 2, rand((2, 4)), (8, 4))
    with pytest.raises(ValueError, match="overlaps"):
        ps.add("a0", 3, rand((2, 4)))
    with pytest.raises(ValueError, match="exceed"):
        ps.add("a0", 7, rand((2, 4)))
    with pytest.raises(ValueError, match="tail"):
        ps.add("a0", 5, rand((1, 3)))
    with pytest.raises(ValueError, match="conflicting full shapes"):
        ps.add("a0", 0, rand((2, 4)), (16, 4))
    with pytest.raises(ValueError, match="full shape"):
        PatchSet().add("b", 3, rand((1, 4)))   # partial span needs shape


def test_patchset_subset_preserves_shapes_and_tree_roundtrip():
    ps = PatchSet()
    ps.add("a0", 0, rand((2, 4)), (16, 4))
    ps.add("a0", 10, rand((3, 4)))
    ps.add("a1", 0, rand(8))
    sub = ps.subset(["a0"])
    assert sub.names() == ["a0"]
    assert sub.shape_of("a0") == (16, 4)       # full extent survives
    assert sub.extents() == {"a0": [[0, 2], [10, 13]]}
    tree = ps.to_tree()
    assert PatchSet.is_tree(tree)
    rt = PatchSet.from_tree(cio.frame_loads(cio.frame_dumps(tree)))
    assert rt.extents() == ps.extents()
    for name in ps:
        for sp, rp in zip(ps[name], rt[name]):
            np.testing.assert_array_equal(np.asarray(sp.data),
                                          np.asarray(rp.data))


def test_mask_to_intervals_bridges_clean_rows_only():
    # dirty runs separated by <= max_gap CLEAN rows coalesce...
    persist = np.array([1, 1, 0, 0, 1, 0, 0, 0, 0, 0, 1], bool)
    clean = ~persist
    assert mask_to_intervals(persist, bridgeable=clean, max_gap=2) \
        == [(0, 5), (10, 11)]
    # ...but a dirty-but-deferred row in the gap blocks the bridge
    deferred = persist.copy()
    deferred[3] = True                          # dirty, below threshold
    assert mask_to_intervals(persist, bridgeable=~deferred, max_gap=2) \
        == [(0, 2), (4, 5), (10, 11)]
    assert mask_to_intervals(np.zeros(4, bool)) == []


def test_merge_span_chain_is_newest_wins_and_zero_copy():
    old = np.full((6, 2), 1.0, np.float32)
    new = np.full((3, 2), 2.0, np.float32)
    merged = merge_span_chain([[Span(0, old)], [Span(2, new)]])
    got = {(sp.start, sp.stop): float(np.asarray(sp.data)[0, 0])
           for sp in merged}
    assert got == {(0, 2): 1.0, (2, 5): 2.0, (5, 6): 1.0}
    # emitted blocks are views into the sources, not copies
    for sp in merged:
        assert np.asarray(sp.data).base is not None


def test_split_sizes_matches_array_split():
    for extent, parts in ((10, 3), (7, 7), (5, 8), (256, 3)):
        expect = [len(c) for c in np.array_split(np.arange(extent), parts)]
        assert split_sizes(extent, parts) == expect


# --------------------------------------------------------------------------
# patch_frame: row-range pwrites
# --------------------------------------------------------------------------

def test_patch_frame_row_spans_roundtrip(tmp_path):
    path = str(tmp_path / "f.ckpt")
    payload = {"a0": rand((16, 4)), "a1": rand(32)}
    cio.save_frame_payload(path, payload)
    ps = PatchSet()
    ps.add("a0", 2, rand((3, 4)), (16, 4))
    ps.add("a0", 9, rand((1, 4)))
    ps.add("a1", 24, rand(8), (32,))
    n = cio.patch_frame(path, ps)
    assert n >= ps.nbytes          # span bytes + the header rewrite
    _, leaves = cio.read_frame(path, verify=True)   # partial sha refreshed
    expect0 = np.array(payload["a0"])
    expect0[2:5] = np.asarray(ps["a0"][0].data)
    expect0[9:10] = np.asarray(ps["a0"][1].data)
    np.testing.assert_array_equal(leaves["a0"], expect0)
    expect1 = np.array(payload["a1"])
    expect1[24:] = np.asarray(ps["a1"][0].data)
    np.testing.assert_array_equal(leaves["a1"], expect1)


def test_patch_frame_rejects_out_of_range_rows(tmp_path):
    path = str(tmp_path / "f.ckpt")
    cio.save_frame_payload(path, {"a0": rand((8, 4))})
    bad = PatchSet()
    bad.add("a0", 6, rand((4, 4)), (10, 4))      # rows 6..10 > leaf's 8
    with pytest.raises(ValueError, match="layout mismatch"):
        cio.patch_frame(path, bad)
    _, leaves = cio.read_frame(path, verify=True)   # file untouched
    assert leaves["a0"].shape == (8, 4)


# --------------------------------------------------------------------------
# row-granular dirty tracking over real MoE configs
# --------------------------------------------------------------------------

RPE = 4          # rows per expert in the downscaled expert table
DM = 8           # downscaled model dim


def moe_replica(arch, granularity="row", rng=None):
    """Downscaled expert tables with the arch's REAL expert count: the
    row extents exercised are the ones expert-parallel routing dirties."""
    cfg = get_config(arch)
    n_exp = cfg.moe.n_experts
    params = {"expert_up": rand((n_exp * RPE, DM), 0.1, rng),
              "router": rand((n_exp, DM), 0.1, rng),
              "gate_bias": rand(DM, 0.1, rng)}
    mu = {k: np.zeros_like(v) for k, v in params.items()}
    nu = {k: np.zeros_like(v) for k, v in params.items()}
    return _NumpyAdam(params, mu, nu, 0, lr=1e-3, track_dirty=True,
                      dirty_granularity=granularity), n_exp


def routed_grads(rep, experts, scale=1.0, rng=None):
    """Gradient touching only the routed experts' rows (plus the shared
    gate bias), as expert-parallel training produces locally."""
    g = {k: np.zeros_like(v) for k, v in rep.params.items()}
    for e in experts:
        g["expert_up"][e * RPE:(e + 1) * RPE] = rand((RPE, DM), scale, rng)
        g["router"][e] = rand(DM, scale, rng)
    g["gate_bias"][:] = rand(DM, scale, rng)
    return g


@pytest.mark.parametrize("arch", ["deepseek-moe-16b", "qwen3-moe-235b-a22b"])
def test_only_routed_experts_rows_persist(arch):
    rep, n_exp = moe_replica(arch)
    rep.snapshot_full()                         # clean baseline
    experts = sorted({3, 17, n_exp - 2})        # spaced > coalesce gap
    rep.apply(routed_grads(rep, experts))
    updates, deferred = rep.snapshot_dirty()
    assert deferred == 0
    up = updates["params"]["expert_up"]
    assert isinstance(up, RowUpdate)
    assert up.extents() == [[e * RPE, (e + 1) * RPE] for e in experts]
    assert up.shape == (n_exp * RPE, DM)
    router = updates["params"]["router"]
    assert router.extents() == [[e, e + 1] for e in experts]
    # the dense leaf persists whole (single full-cover span => plain
    # array, bit-identical blob to leaf granularity)
    assert isinstance(updates["params"]["gate_bias"], np.ndarray)
    # moments ride the same intervals
    assert updates["mu"]["expert_up"].extents() == up.extents()
    assert updates["nu"]["expert_up"].extents() == up.extents()
    # everything row-tracked is clean now
    assert rep.snapshot_dirty()[0]["params"] == {}


def test_row_threshold_defers_individual_rows():
    rep, _ = moe_replica("deepseek-moe-16b")
    rep.snapshot_full()
    rep.apply(routed_grads(rep, [2]))           # one ~lr-sized nudge
    for _ in range(40):
        rep.apply(routed_grads(rep, [30]))      # accumulates real drift
    updates, deferred = rep.snapshot_dirty(threshold=0.02)
    up = updates["params"]["expert_up"]
    assert isinstance(up, RowUpdate)
    assert up.extents() == [[30 * RPE, 31 * RPE]]   # expert 2 deferred
    # the deferred rows stay dirty and persist once they move enough
    for _ in range(40):
        rep.apply(routed_grads(rep, [2]))
    updates, _ = rep.snapshot_dirty(threshold=0.02)
    assert updates["params"]["expert_up"].extents() == [[2 * RPE, 3 * RPE]]


def test_remark_dirty_restores_row_spans():
    rep, _ = moe_replica("deepseek-moe-16b")
    rep.snapshot_full()
    rep.apply(routed_grads(rep, [5]))
    updates, _ = rep.snapshot_dirty()
    assert rep.snapshot_dirty()[0]["params"] == {}   # clean after snapshot
    rep.remark_dirty(updates)                        # persist "failed"
    again, deferred = rep.snapshot_dirty(threshold=1e9)  # beats any filter
    assert deferred == 0
    assert again["params"]["expert_up"].extents() \
        == updates["params"]["expert_up"].extents()


# --------------------------------------------------------------------------
# recovery: row chains bit-identical to full-leaf mode, all 5 backends
# --------------------------------------------------------------------------

def mk_backend_store(tmp_path, kind):
    root = str(tmp_path / kind)
    if kind == "local":
        return make_store(root)
    if kind == "sharded":
        return make_store(root, backend="sharded", shards=3)
    if kind == "memory":
        return make_store(root, backend="memory")
    if kind == "remote":
        be = RemoteObjectBackend(FakeObjectStore(), chunk_bytes=4096,
                                 journal_root=root)
        return CheckpointStore(backend=be)
    if kind == "peer":
        cfg = StoreConfig.from_legacy(
            root, peers=2, peer_hub=f"rg_{os.path.basename(str(tmp_path))}",
            simulate_peers=True)
        return cfg.build()
    raise AssertionError(kind)


def drive_chain(store, granularity):
    """Same deterministic routed-sparse workload at either granularity
    (fresh seeded rng per call, so row and leaf runs see identical
    bytes); returns (base key, replica)."""
    rng = np.random.default_rng(29)
    rep, n_exp = moe_replica("deepseek-moe-16b", granularity, rng)
    base = store.save_full(1, rep.snapshot_full(), record_names=True)
    for step, experts in enumerate(([1, 9], [9, 40], [62], [1, 33]), 2):
        rep.apply(routed_grads(rep, experts, rng=rng))
        updates, _ = rep.snapshot_dirty()
        store.save_patch(step, base, updates)
    return base, rep


@pytest.mark.parametrize("kind", ["local", "sharded", "memory",
                                  "remote", "peer"])
def test_row_chain_recovers_bit_identical(tmp_path, kind):
    store = mk_backend_store(tmp_path, kind)
    base, rep = drive_chain(store, "row")
    got, step = store.load_latest_state()
    assert step == 5
    assert_state_equal(rep.state(), got, f"{kind} row chain")

    # a leaf-granular replica fed the same gradients lands on the same
    # bytes — row mode changed what is WRITTEN, never what is recovered
    leaf_store = make_store(str(tmp_path / f"{kind}_leaf"))
    lbase, lrep = drive_chain(leaf_store, "leaf")
    lgot, _ = leaf_store.load_latest_state()
    assert_state_equal(lgot, got, f"{kind} row vs leaf recovery")

    # folding the row chain stays identical and retires the chain
    assert store.fold_sync(merge_slice=2) == 4
    assert store.manifest.get("patches", []) == []
    entry = store.latest_full()
    assert entry["state_step"] == 5
    assert_state_equal(rep.state(), store.load_full(entry), f"{kind} fold")
    assert store.backend.verify(base) is None

    if kind == "memory":
        store.backend.flush()            # range write-back reached disk
        assert_state_equal(rep.state(), store.backend.lower.get(base),
                           "memory lower tier")
        assert store.backend.lower.verify(base) is None
    if kind == "peer":
        store.backend.flush()            # range PATCHes replicated
        store.backend.lower.delete(base)
        assert_state_equal(rep.state(), store.backend.get(base),
                           "peer replica after local loss")
    store.close()
    leaf_store.close()


def test_row_and_leaf_patches_mix_in_one_chain(tmp_path):
    """Old leaf-granular blobs and new row-granular blobs interleave in
    one chain (rolling upgrade): recovery overlays both in order."""
    store = make_store(str(tmp_path / "mix"))
    state = {"params": {"w": rand((32, 4)), "b": rand(8)},
             "mu": {"w": rand((32, 4)), "b": rand(8)},
             "nu": {"w": np.abs(rand((32, 4))), "b": np.abs(rand(8))},
             "count": np.array(1, np.int64)}
    base = store.save_full(1, state, record_names=True)
    expected = deep_copy_state(state)
    legacy = {"params": {"w": rand((32, 4))}, "mu": {}, "nu": {},
              "count": np.array(2, np.int64)}
    store.save_patch(2, base, legacy)
    merge_updates(expected, legacy)
    rowu = {"params": {"w": row_update_from_spans(
                [Span(4, rand((2, 4))), Span(20, rand((3, 4)))], (32, 4))},
            "mu": {}, "nu": {}, "count": np.array(3, np.int64)}
    store.save_patch(3, base, rowu)
    merge_updates(expected, rowu)
    got, step = store.load_latest_state()
    assert step == 3
    assert_state_equal(expected, got, "mixed chain")
    # journal entry records the row extents for the row patch only
    patches = store.manifest["patches"]
    assert "extents" not in patches[0]
    assert list(patches[1]["extents"].values()) == [[[4, 6], [20, 23]]]
    assert store.fold_sync() == 2
    assert_state_equal(expected, store.load_full(store.latest_full()),
                       "mixed fold")
    store.close()


# --------------------------------------------------------------------------
# crash injection at range-patch and range-fold boundaries
# --------------------------------------------------------------------------

class Killed(RuntimeError):
    pass


def build_row_patched_store(root):
    store = make_store(root)
    rep, _ = moe_replica("deepseek-moe-16b")
    base = store.save_full(1, rep.snapshot_full(), record_names=True)
    expected = deep_copy_state(rep.state())
    expected["count"] = np.array(rep.count, np.int64)
    for step, experts in enumerate(([2, 50], [7], [2, 19]), 2):
        rep.apply(routed_grads(rep, experts))
        updates, _ = rep.snapshot_dirty()
        store.save_patch(step, base, updates)
        merge_updates(expected, updates)
    return store, base, expected


@pytest.mark.parametrize("point", ["patch:mid_span", "patch:mid_data",
                                   "patch:pre_header", "patch:mid_header"])
def test_crash_inside_range_patch_recovers_bit_identical(tmp_path, point):
    """A kill between two row-span pwrites (new boundary), between
    leaves, or around the header rewrite leaves torn ranges — the patch
    chain replays over them on restart."""
    store, base, expected = build_row_patched_store(str(tmp_path / "s"))

    def hook(p):
        if p == point:
            raise Killed(p)
    cio.set_patch_crash_hook(hook)
    try:
        with pytest.raises(Killed):
            store.fold_sync()
    finally:
        cio.set_patch_crash_hook(None)
    store.journal.close()

    store2 = make_store(str(tmp_path / "s"))
    got, step = store2.load_latest_state()
    assert step == 4
    assert_state_equal(expected, got, f"after {point}")
    assert store2.fold_sync() == 3
    assert_state_equal(expected, store2.load_full(store2.latest_full()),
                       f"refold after {point}")
    assert store2.backend.verify(base) is None
    store2.close()


def kill_at(svc, point):
    state = {"armed": True}

    def hook(p):
        if p == point and state["armed"]:
            state["armed"] = False
            raise InjectedCrash(p)
    svc.crash_hook = hook
    return state


def wait_dead(svc, timeout=10.0):
    deadline = time.monotonic() + timeout
    while svc.running and time.monotonic() < deadline:
        time.sleep(0.005)
    assert not svc.running, "worker survived the injected crash"


@pytest.mark.parametrize("point", ["fold:planned", "fold:patched_slice",
                                   "fold:cursored", "fold:folded"])
def test_crash_at_range_fold_boundaries_resumes(tmp_path, point):
    root = str(tmp_path / "s")
    store, base, expected = build_row_patched_store(root)
    svc = MaintenanceService(store, merge_slice=2)
    store.attach_maintenance(svc)
    svc.start()
    kill_at(svc, point)
    svc.request_fold()
    wait_dead(svc)
    svc.stop()
    store.journal.close()

    store2 = make_store(root)
    svc2 = MaintenanceService(store2, merge_slice=2)
    store2.attach_maintenance(svc2)
    svc2.start()
    svc2.drain(30.0)
    assert store2.manifest.get("patches", []) == []
    entry = store2.latest_full()
    assert entry["state_step"] == 4
    assert_state_equal(expected, store2.load_full(entry), f"after {point}")
    assert store2.backend.verify(base) is None
    assert svc2.fold_runs >= 1
    store2.close()


# --------------------------------------------------------------------------
# fold stress: thousands of tiny patches, bounded journal + memory
# --------------------------------------------------------------------------

def test_thousand_tiny_patches_fold_bounded(tmp_path):
    root = str(tmp_path / "tiny")
    store = make_store(root)
    rows, dm = 2048, 4
    state = {"params": {"big": rand((rows, dm))},
             "count": np.array(1, np.int64)}
    base = store.save_full(1, state, record_names=True)
    expected = deep_copy_state(state)
    n_patches = 1000
    touched = set()
    for i in range(n_patches):
        r = (i * 37) % rows
        touched.add(r)
        upd = {"params": {"big": row_update_from_spans(
                   [Span(r, rand((1, dm)))], (rows, dm))},
               "count": np.array(2 + i, np.int64)}
        store.save_patch(2 + i, base, upd)
        merge_updates(expected, upd)

    # newest-wins merge dedups re-touched rows and never materializes
    # the full leaf: merged bytes == distinct touched rows (+ count)
    keys = [store._entry_key(e) for e in store.manifest["patches"]]
    merged = store.fold_updates(base, keys)
    assert isinstance(merged, PatchSet)
    row_bytes = dm * 4
    assert merged.nbytes <= len(touched) * row_bytes + 16

    log = os.path.join(root, "manifest.log")
    before = sum(1 for _ in open(log, "rb"))
    assert store.fold_sync(merge_slice=1) == n_patches
    after = sum(1 for _ in open(log, "rb"))
    # the fold's journal growth is one del per retired patch entry plus
    # a BOUNDED progress tail (plan/slices/cursors/commit) — it must not
    # scale with patch count a second time
    assert after - before <= n_patches + 40, (before, after)
    assert store.manifest.get("patches", []) == []
    entry = store.latest_full()
    assert entry["state_step"] == 1 + n_patches
    assert_state_equal(expected, store.load_full(entry), "tiny fold")
    assert store.backend.verify(base) is None
    store.close()


# --------------------------------------------------------------------------
# signature sync + adaptive fold trigger
# --------------------------------------------------------------------------

def test_backend_patch_signatures_stay_in_sync():
    """The drifting per-backend patch signatures unified on PatchSet:
    any new backend (or edit) must keep the exact ABC signature."""
    base = inspect.signature(StorageBackend.patch)
    impls = [LocalFSBackend, ShardedBackend, MemoryTierBackend,
             RemoteObjectBackend, PeerReplicaBackend]
    for cls in impls:
        assert cls.patch is not StorageBackend.patch, cls  # real override
        assert inspect.signature(cls.patch) == base, (
            f"{cls.__name__}.patch drifted from StorageBackend.patch")


def test_fold_due_policy():
    assert not fold_due(100, 0, 99.0, 1.5)        # 0 = never fold
    assert fold_due(16, 16, 0.0, 1.5)             # count cap
    assert fold_due(3, 16, 1.5, 1.5)              # amplification trigger
    assert not fold_due(3, 16, 1.4, 1.5)
    assert not fold_due(3, 16, 99.0, 0.0)         # adaptive disabled


def test_chain_amplification_tracks_overlay_bytes(tmp_path):
    store = make_store(str(tmp_path / "amp"))
    state = {"params": {"w": rand((64, 8))}, "count": np.array(1, np.int64)}
    base = store.save_full(1, state, record_names=True)
    assert store.chain_amplification() == 0.0
    base_bytes = next(e["bytes"] for e in store.manifest["fulls"]
                      if store._entry_key(e) == base)
    total = 0
    for step in range(2, 6):
        upd = {"params": {"w": row_update_from_spans(
                   [Span(4, rand((8, 8)))], (64, 8))},
               "count": np.array(step, np.int64)}
        store.save_patch(step, base, upd)
        total += next(e["bytes"] for e in store.manifest["patches"]
                      if e["step"] == step)
        amp = store.chain_amplification()
        assert amp == pytest.approx(total / base_bytes)
    st = store.stats()
    assert st["chain_amplification"] == pytest.approx(total / base_bytes)
    assert st["max_amplification"] >= st["chain_amplification"]
    # folding retires the chain: live amplification drops to zero, the
    # high-water mark survives for the adaptive trigger's telemetry
    store.fold_sync()
    assert store.chain_amplification() == 0.0
    assert store.stats()["max_amplification"] == pytest.approx(
        total / base_bytes)
    store.close()
