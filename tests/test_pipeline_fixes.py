"""Regression tests for the checkpointing-pipeline liveness fixes.

Each test pins one of the bugs that would corrupt or deadlock a long
run against a slow remote tier:
  * a poisoned persist handler makes flush() raise (bounded) instead of
    busy-waiting forever on a counter the dead consumer can't advance
  * the online tuner's re-solved (f, b) actually propagates to
    full_interval/batch_size (the paper's dynamic adaptation was dead)
  * ReusingQueue.close() never blocks on a full queue, and the shutdown
    sentinel is not counted as a dequeued differential
  * a step present both as a standalone diff blob and inside a batch
    blob replays exactly once (standalone wins) — double-applying it
    through Adam advances the moments twice and corrupts recovery
"""
import time

import jax
import numpy as np
import pytest

from repro.checkpoint.backends import LocalFSBackend
from repro.checkpoint.store import CheckpointStore
from repro.configs import get_config
from repro.core import recovery as rec
from repro.core.lowdiff import LowDiff
from repro.core.lowdiff_plus import LowDiffPlus
from repro.core.reusing_queue import CheckpointingError, ReusingQueue
from repro.core.steps import init_state
from repro.data.synthetic import make_batch
from repro.models.registry import build_model
from repro.optim.adam import AdamState

SEQ, BATCH = 32, 2


@pytest.fixture(scope="module")
def tiny_model():
    return build_model(get_config("qwen2-1.5b").reduced())


# --------------------------------------------------------------------------
# flush() liveness
# --------------------------------------------------------------------------

def test_poisoned_handler_flush_raises_not_hangs(tiny_model, tmp_path):
    """An exception in the consumer's handler used to kill the drain
    thread silently; flush() then spun forever. It must now re-raise
    the handler error, well inside the deadline."""
    store = CheckpointStore(str(tmp_path / "ck"))
    ld = LowDiff(tiny_model, store, full_interval=100, batch_size=2,
                 parallel_recovery=False)

    def poisoned(step, cg):
        raise RuntimeError("persist tier exploded")

    ld._handle = poisoned
    state = init_state(tiny_model, jax.random.PRNGKey(0), mode="lowdiff")
    state, _ = ld.train_step(state, make_batch(tiny_model.cfg, SEQ, BATCH))
    t0 = time.monotonic()
    with pytest.raises(CheckpointingError) as ei:
        ld.flush(timeout=30.0)
    assert time.monotonic() - t0 < 10.0       # raised, not deadline-waited
    assert "persist tier exploded" in str(ei.value.__cause__)
    # the consumer must NOT be silently restarted over the poisoned
    # queue: persisting later batches past the lost one would durably
    # write a chain with an undetectable hole
    with pytest.raises(CheckpointingError, match="previously failed"):
        ld.train_step(state, make_batch(tiny_model.cfg, SEQ, BATCH))
    # close() surfaces the same failure instead of pretending all is well
    with pytest.raises(CheckpointingError):
        ld.close()


def test_flush_raises_when_consumer_never_ran(tiny_model, tmp_path):
    store = CheckpointStore(str(tmp_path / "ck"))
    ld = LowDiff(tiny_model, store, full_interval=100, batch_size=2)
    ld.queue.put(1, {"g": np.zeros(4, np.float32)})   # consumer never started
    with pytest.raises(CheckpointingError, match="not running"):
        ld.flush(timeout=5.0)
    store.close()


def test_flush_deadline_bounds_wait(tiny_model, tmp_path):
    """A wedged (not dead) consumer must not stall flush forever: the
    deadline turns the hang into a TimeoutError."""
    store = CheckpointStore(str(tmp_path / "ck"))
    ld = LowDiff(tiny_model, store, full_interval=100, batch_size=2)

    def wedged(step, cg):
        time.sleep(5.0)
        ld._processed += 1

    ld._handle = wedged
    state = init_state(tiny_model, jax.random.PRNGKey(0), mode="lowdiff")
    ld.train_step(state, make_batch(tiny_model.cfg, SEQ, BATCH))
    with pytest.raises(TimeoutError):
        ld.flush(timeout=0.3)
    # let the wedged consumer finish so teardown is clean
    ld.flush(timeout=30.0)
    ld.close()


def test_lowdiff_plus_poisoned_persist_flush_raises(tiny_model, tmp_path):
    store = CheckpointStore(str(tmp_path / "ckp"))
    ldp = LowDiffPlus(tiny_model, store, persist_interval=1)

    def poisoned(step, futures):
        raise OSError("replica persist failed")

    ldp._handle = poisoned
    state = init_state(tiny_model, jax.random.PRNGKey(0),
                       mode="lowdiff_plus")
    ldp.train_step(state, make_batch(tiny_model.cfg, SEQ, BATCH))
    with pytest.raises(CheckpointingError) as ei:
        ldp.flush(timeout=30.0)
    assert isinstance(ei.value.__cause__, OSError)
    with pytest.raises(CheckpointingError):
        ldp.close()


# --------------------------------------------------------------------------
# dynamic tuning
# --------------------------------------------------------------------------

def test_tuner_updates_propagate_in_auto_mode(tiny_model, tmp_path):
    """LowDiff fed the tuner merge times but never read current() back:
    (f, b) stayed at the Eq. (10) seed forever. After a batch flush the
    re-solved config must now be applied and recorded."""
    store = CheckpointStore(str(tmp_path / "tune"))
    ld = LowDiff(tiny_model, store)        # no overrides: auto (f, b)
    f0, b0 = ld.full_interval, ld.batch_size
    pay = {"g": np.zeros(16, np.float32)}
    ld._buffer = [(1, pay), (2, pay)]
    ld._flush_batch()
    # observed merge time (~ms) is far below the R_D prior (0.5 iter):
    # the EMA drops R_D, so b* shrinks and the full interval stretches
    assert (ld.full_interval, ld.batch_size) != (f0, b0)
    assert ld.full_interval > f0
    assert ld.batch_size < b0
    tuning = ld.stats()["tuning"]
    assert tuning["auto"] == {"full_interval": True, "batch_size": True}
    assert tuning["applied"] >= 1
    assert len(tuning["history"]) == 1
    assert tuning["history"][0]["applied"] is True
    # more observations keep converging, never diverge to nonsense
    for s in range(3, 9, 2):
        ld._buffer = [(s, pay), (s + 1, pay)]
        ld._flush_batch()
    assert 1 <= ld.batch_size <= b0
    assert len(ld.stats()["tuning"]["history"]) == 4
    store.close()


def test_tuner_respects_pinned_config(tiny_model, tmp_path):
    """Explicit (f, b) are pinned: the tuner records its recommendation
    but must not override the caller's choice."""
    store = CheckpointStore(str(tmp_path / "pin"))
    ld = LowDiff(tiny_model, store, full_interval=5, batch_size=2)
    pay = {"g": np.zeros(16, np.float32)}
    ld._buffer = [(1, pay), (2, pay)]
    ld._flush_batch()
    assert (ld.full_interval, ld.batch_size) == (5, 2)
    tuning = ld.stats()["tuning"]
    assert tuning["applied"] == 0
    assert len(tuning["history"]) == 1
    assert tuning["history"][0]["applied"] is False
    assert tuning["history"][0]["batch_size"] != 2   # it did re-solve
    store.close()


# --------------------------------------------------------------------------
# queue shutdown semantics
# --------------------------------------------------------------------------

def test_queue_close_nonblocking_on_full_queue():
    q = ReusingQueue(maxsize=2)
    q.put(1, "a")
    q.put(2, "b")                       # queue is now full
    t0 = time.monotonic()
    q.close()                           # used to block in _q.put()
    assert time.monotonic() - t0 < 0.5
    seen = []
    q.drain(lambda s, p: seen.append(s))
    assert seen == [1, 2]               # closed flag still drains the backlog


def test_queue_sentinel_not_counted_in_dequeued():
    q = ReusingQueue(maxsize=8)
    q.put(1, "a")
    q.put(2, "b")
    q.close()                           # room for the sentinel this time
    q.drain(lambda s, p: None)
    st = q.stats()
    assert st["enqueued"] == 2
    assert st["dequeued"] == 2          # sentinel excluded


def test_queue_drain_captures_handler_error():
    q = ReusingQueue(maxsize=8)
    q.put(1, "a")
    q.put(2, "b")

    def boom(step, payload):
        raise ValueError("bad payload")

    q.drain(boom)                       # returns instead of raising
    assert isinstance(q.error, ValueError)
    assert q.stats()["consumer_error"] is not None


# --------------------------------------------------------------------------
# diffs_after double-apply
# --------------------------------------------------------------------------

class CountingBackend(LocalFSBackend):
    def __init__(self, root):
        super().__init__(root)
        self.gets = 0

    def get(self, key):
        self.gets += 1
        return super().get(key)


def _grad(step):
    return {"w": np.full(8, 0.1 * step, np.float32)}


def test_diffs_after_dedups_standalone_and_batch(tmp_path):
    """A step present both as diff_* and inside batch_* must be returned
    once, from the standalone blob."""
    store = CheckpointStore(backend=CountingBackend(str(tmp_path / "d")))
    store.save_batch(1, 3, [_grad(1), _grad(2), _grad(3)])
    marker = {"w": np.full(8, 99.0, np.float32)}
    store.save_diff(2, marker)          # duplicate of batch step 2
    out = store.diffs_after(0)
    assert [s for s, _ in out] == [1, 2, 3]
    np.testing.assert_array_equal(dict(out)[2]["w"], marker["w"])
    store.close()


def test_diffs_after_skips_fully_covered_batch(tmp_path):
    be = CountingBackend(str(tmp_path / "c"))
    store = CheckpointStore(backend=be)
    store.save_batch(1, 2, [_grad(1), _grad(2)])
    store.save_diff(1, _grad(1))
    store.save_diff(2, _grad(2))
    be.gets = 0
    out = store.diffs_after(0)
    assert [s for s, _ in out] == [1, 2]
    assert be.gets == 2                 # the redundant batch never fetched
    store.close()


def test_contiguous_prefix_cuts_at_first_gap():
    """A mid-chain hole (a differential whose write-back never landed)
    must truncate replay, never be skipped over."""
    diffs = [(5, "a"), (6, "b"), (8, "c"), (9, "d")]   # 7 is missing
    assert rec.contiguous_prefix(4, diffs) == [(5, "a"), (6, "b")]
    assert rec.contiguous_prefix(4, []) == []
    assert rec.contiguous_prefix(6, [(8, "c")]) == []  # gap at the head
    assert rec.contiguous_prefix(4, [(6, "x"), (8, "y")],
                                 stride=2) == [(6, "x"), (8, "y")]


def test_lowdiff_recover_stops_at_writeback_hole(tmp_path, tiny_model):
    """LowDiff recovery over a manifest with a mid-chain hole recovers
    to the last consistent step instead of replaying across the gap."""
    store = CheckpointStore(str(tmp_path / "hole"))
    ld = LowDiff(tiny_model, store, rho=0.05, lr=1e-3, full_interval=4,
                 batch_size=2, parallel_recovery=False)
    state = init_state(tiny_model, jax.random.PRNGKey(0), mode="lowdiff")
    for t in range(9):
        state, _ = ld.train_step(state, make_batch(tiny_model.cfg, SEQ,
                                                   BATCH, step=t))
    ld.flush()
    # simulate the crash pattern _prune_missing cannot repair: the
    # newest full AND a mid-chain batch both lost (failed write-backs)
    for key, kind in (("full_00000008", "fulls"),
                      ("batch_00000005_00000006", "batches")):
        store.journal.append("del", kind, key=key)
        store.backend.delete(key)
    rec_state, n = ld.recover()
    # chain from full@4 is 5,6(missing),7,8,9 -> nothing replayable
    # past the hole at 5: recover lands exactly on the full@4 state
    assert n == 0
    assert int(rec_state["step"]) == 4
    ld.close()


def test_duplicate_replay_bit_identical_to_clean_chain(tmp_path):
    """Replaying a chain that contains a duplicated step must produce
    exactly the bytes of the duplicate-free chain — double-applying a
    differential through Adam advances count/moments twice."""
    params = {"w": np.linspace(-1, 1, 8).astype(np.float32)}
    opt = AdamState(mu={"w": np.zeros(8, np.float32)},
                    nu={"w": np.zeros(8, np.float32)},
                    count=np.zeros((), np.int32))

    clean = CheckpointStore(backend=LocalFSBackend(str(tmp_path / "a")))
    clean.save_batch(1, 3, [_grad(1), _grad(2), _grad(3)])
    dup = CheckpointStore(backend=LocalFSBackend(str(tmp_path / "b")))
    dup.save_batch(1, 3, [_grad(1), _grad(2), _grad(3)])
    dup.save_diff(2, _grad(2))          # the double-write

    p_clean, o_clean = rec.replay_serial(params, opt,
                                         clean.diffs_after(0), lr=1e-3)
    p_dup, o_dup = rec.replay_serial(params, opt,
                                     dup.diffs_after(0), lr=1e-3)
    np.testing.assert_array_equal(np.asarray(p_clean["w"]),
                                  np.asarray(p_dup["w"]))
    np.testing.assert_array_equal(np.asarray(o_clean.mu["w"]),
                                  np.asarray(o_dup.mu["w"]))
    np.testing.assert_array_equal(np.asarray(o_clean.nu["w"]),
                                  np.asarray(o_dup.nu["w"]))
    assert int(o_clean.count) == int(o_dup.count) == 3
    clean.close()
    dup.close()
