"""Maintenance-service tests: crash-resumable GC, integrity scrub,
journal segments, eviction policies.

Covers the subsystem's acceptance criteria:
  * a kill at any journaled GC/scrub/merge boundary loses no live-chain
    blob and leaks no dead blob after one resumed pass
  * the scrubber quarantines corrupt blobs so recovery skips them
    proactively (fall back to an older full / cut the chain at the gap)
  * multi-host segmented journals recover bit-identical state to the
    single-journal path, including across a crash mid-merge
  * eviction policy variants (fifo/lru over size-class buckets) with
    the chain-protection guard unchanged
  * flush() drains pending maintenance slices with the persist queue's
    deadline/error-surfacing contract
"""
import os
import time

import numpy as np
import pytest

from repro.checkpoint import (CheckpointStore, LocalFSBackend,
                              MemoryTierBackend, ShardedBackend, make_store)
from repro.checkpoint.journal import ManifestJournal, SegmentedManifestJournal
from repro.checkpoint.remote import FakeObjectStore, RemoteObjectBackend
from repro.core.reusing_queue import CheckpointingError
from repro.maintenance import InjectedCrash, MaintenanceService

PAY_N = 64


def pay(s):
    return {"g": np.full(PAY_N, float(s), np.float32)}


def full_state(s):
    return {"params": pay(s), "step": np.int32(s)}


def build_chain(store, fulls=(4, 8, 12, 16)):
    """full@4..16 with three diffs before each — GC at retention 2
    dooms 2 fulls + 9 diffs."""
    for step in fulls:
        for d in range(step - 3, step):
            store.save_diff(d, pay(d))
        store.save_full(step, full_state(step))


def manifest_keys(store):
    keys = set()
    for kind in ("fulls", "diffs", "batches", "quarantined"):
        for e in store.manifest.get(kind, []):
            keys.add(store._entry_key(e))
    return keys


def assert_no_leak_no_loss(store):
    """Backend holds exactly the blobs the manifest references: nothing
    stranded on disk, nothing referenced but missing."""
    refd = manifest_keys(store)
    on_disk = set(store.backend.keys())
    assert on_disk - refd == set(), f"leaked blobs: {on_disk - refd}"
    assert refd - on_disk == set(), f"lost blobs: {refd - on_disk}"


def kill_at(svc, point, once=True):
    """Arm the crash seam: the worker dies (journaling nothing further)
    the first time it reaches `point`."""
    state = {"armed": True}

    def hook(p):
        if p == point and state["armed"]:
            if once:
                state["armed"] = False
            raise InjectedCrash(p)
    svc.crash_hook = hook
    return state


def wait_dead(svc, timeout=10.0):
    deadline = time.monotonic() + timeout
    while svc.running and time.monotonic() < deadline:
        time.sleep(0.005)
    assert not svc.running, "worker survived the injected crash"


def restart(root, retention=2, gc_slice=2):
    """Simulate a process restart: fresh store from disk + fresh
    service that resumes journaled work on start()."""
    store = make_store(root, retention_fulls=retention)
    svc = MaintenanceService(store, gc_slice=gc_slice)
    store.attach_maintenance(svc)
    svc.start()
    svc.drain(30.0)
    return store, svc


# --------------------------------------------------------------------------
# resumable GC: service path == synchronous path
# --------------------------------------------------------------------------

def test_service_gc_matches_sync_gc(tmp_path):
    sync_store = make_store(str(tmp_path / "sync"))
    build_chain(sync_store)
    sync_store.gc(retention_fulls=2)

    svc_store = make_store(str(tmp_path / "svc"))
    build_chain(svc_store)
    svc = MaintenanceService(svc_store, gc_slice=3)
    svc_store.attach_maintenance(svc)
    svc.start()
    svc.request_gc(2)
    svc_store.flush()
    assert manifest_keys(svc_store) == manifest_keys(sync_store)
    assert sorted(svc_store.backend.keys()) == sorted(
        sync_store.backend.keys())
    assert_no_leak_no_loss(svc_store)
    svc_store.close()
    sync_store.close()


def test_request_gc_sync_fallback_without_service(tmp_path):
    """--maintenance off path: request_gc sweeps synchronously."""
    store = make_store(str(tmp_path / "fb"), retention_fulls=2)
    build_chain(store)
    # save_full triggered request_gc -> sync gc (no service attached)
    assert [e["step"] for e in store.manifest["fulls"]] == [12, 16]
    assert_no_leak_no_loss(store)
    store.close()


# --------------------------------------------------------------------------
# crash injection: kill the worker at every journaled GC boundary
# --------------------------------------------------------------------------

@pytest.mark.parametrize("point", ["gc:marked", "gc:mid_delete",
                                   "gc:swept_slice", "gc:cursored"])
def test_gc_crash_then_resume_loses_nothing(tmp_path, point):
    root = str(tmp_path / "crash")
    store = make_store(root)
    build_chain(store)
    svc = MaintenanceService(store, gc_slice=2)
    store.attach_maintenance(svc)
    kill_at(svc, point)
    svc.start()
    svc.request_gc(2)
    wait_dead(svc)
    # the dead worker's pending work surfaces as an error, never a hang
    with pytest.raises(CheckpointingError):
        svc.drain(1.0)
    store.journal.close()

    store2, svc2 = restart(root)
    assert svc2.resumed >= 1
    # one resumed pass: no dead blob leaked, no live-chain blob lost
    assert_no_leak_no_loss(store2)
    assert [e["step"] for e in store2.manifest["fulls"]] == [12, 16]
    replay = store2.diffs_after(12)
    assert [s for s, _ in replay] == [13, 14, 15]
    for s, p in replay:
        np.testing.assert_array_equal(p["g"], pay(s)["g"])
    store2.close()


def test_gc_resume_in_process_restarted_service(tmp_path):
    """The service object can also be restarted in-process (software
    failure of just the worker): start() re-enqueues the journaled
    task."""
    root = str(tmp_path / "inproc")
    store = make_store(root)
    build_chain(store)
    svc = MaintenanceService(store, gc_slice=2)
    store.attach_maintenance(svc)
    kill_at(svc, "gc:swept_slice")
    svc.start()
    svc.request_gc(2)
    wait_dead(svc)
    svc2 = MaintenanceService(store, gc_slice=2)
    store.attach_maintenance(svc2)
    svc2.start()
    svc2.drain(30.0)
    assert svc2.resumed == 1
    assert_no_leak_no_loss(store)
    assert [e["step"] for e in store.manifest["fulls"]] == [12, 16]
    store.close()


def test_gc_apply_skips_keys_back_in_live_chain(tmp_path):
    """A stale plan must never delete a key that re-entered the newest
    retained chains (same-step re-put between mark and sweep)."""
    store = make_store(str(tmp_path / "stale"))
    build_chain(store, fulls=(4, 8))
    doomed = store.gc_plan(retention_fulls=1)
    assert ("fulls", "full_00000004") in doomed
    # the doomed full is re-saved before the sweep runs -> newest full
    store.save_full(4, full_state(4))
    # now retention 1 keeps full@8's chain... but full@4 is older; make
    # it the newest retained by re-putting the *newest* step instead:
    doomed2 = store.gc_plan(retention_fulls=1)
    store.gc_apply(doomed2, retention_fulls=1)
    # newest full (8) and its chain survive whatever the stale plan said
    assert store.latest_full()["step"] == 8
    assert store.backend.exists("full_00000008")
    assert_no_leak_no_loss(store)
    store.close()


# --------------------------------------------------------------------------
# integrity scrubber: quarantine + proactive recovery skip
# --------------------------------------------------------------------------

def corrupt_file_tail(path):
    with open(path, "r+b") as f:
        f.seek(-1, os.SEEK_END)
        last = f.read(1)
        f.seek(-1, os.SEEK_END)
        f.write(bytes([last[0] ^ 0xFF]))


def test_scrub_quarantines_corrupt_full_and_recovery_falls_back(tmp_path):
    from repro.core import recovery as recmod
    root = str(tmp_path / "scrub")
    store = make_store(root)
    store.save_full(4, full_state(4))
    for s in (5, 6):
        store.save_diff(s, pay(s))
    store.save_full(6, full_state(6))
    store.save_diff(7, pay(7))
    # flip a data byte of the NEWEST full on disk
    corrupt_file_tail(os.path.join(root, "full_00000006.ckpt"))

    svc = MaintenanceService(store, scrub_slice=2)
    store.attach_maintenance(svc)
    svc.start()
    svc.request_scrub()
    store.flush()
    assert svc.corrupt_found == 1
    q = store.manifest["quarantined"]
    assert len(q) == 1 and q[0]["key"] == "full_00000006"
    assert q[0]["src_kind"] == "fulls" and "sha256" in q[0]["reason"]
    # proactive skip: recovery starts from full@4 without ever touching
    # the corrupt blob, and replays the longer diff chain
    state, diffs = recmod.load_latest_chain(store)
    assert int(state["step"]) == 4
    assert [s for s, _ in diffs] == [5, 6, 7]
    store.close()


def test_scrub_quarantined_diff_cuts_chain_at_gap(tmp_path):
    root = str(tmp_path / "qdiff")
    store = make_store(root)
    store.save_full(4, full_state(4))
    for s in (5, 6, 7):
        store.save_diff(s, pay(s))
    corrupt_file_tail(os.path.join(root, "diff_00000006.ckpt"))
    svc = MaintenanceService(store)
    store.attach_maintenance(svc)
    svc.start()
    svc.request_scrub()
    store.flush()
    assert svc.corrupt_found == 1
    # the quarantined diff leaves a step gap; a stride-1 strategy cuts
    # its replay there instead of replaying across the hole
    from repro.core.recovery import contiguous_prefix
    diffs = store.diffs_after(4)
    assert [s for s, _ in diffs] == [5, 7]
    assert [s for s, _ in contiguous_prefix(4, diffs)] == [5]
    store.close()


def test_scrub_crash_then_resume_completes(tmp_path):
    root = str(tmp_path / "scrubcrash")
    store = make_store(root)
    build_chain(store, fulls=(4, 8))
    corrupt_file_tail(os.path.join(root, "diff_00000007.ckpt"))
    svc = MaintenanceService(store, scrub_slice=2)
    store.attach_maintenance(svc)
    kill_at(svc, "scrub:cursored")
    svc.start()
    svc.request_scrub()
    wait_dead(svc)
    store.journal.close()

    store2 = make_store(root)
    svc2 = MaintenanceService(store2, scrub_slice=2)
    store2.attach_maintenance(svc2)
    svc2.start()
    svc2.drain(30.0)
    assert svc2.resumed == 1
    assert len(store2.manifest["quarantined"]) == 1
    assert store2.manifest["quarantined"][0]["key"] == "diff_00000007"
    # quarantine is idempotent across the crash: exactly one record
    # even if the corrupt blob's slice re-ran
    store2.close()


def test_scrub_remote_chunk_corruption_quarantined(tmp_path):
    obj = FakeObjectStore()
    be = RemoteObjectBackend(obj, chunk_bytes=256,
                             journal_root=str(tmp_path / "rj"))
    store = CheckpointStore(backend=be)
    store.save_full(4, full_state(4))
    store.save_full(8, full_state(8))
    # corrupt one stored chunk of full@8 in the bucket itself
    name = next(n for n in obj.list_objects("full_00000008/")
                if n.endswith(".chunk"))
    obj._objects[name] = b"\xff" + obj._objects[name][1:]
    svc = MaintenanceService(store)
    store.attach_maintenance(svc)
    svc.start()
    svc.request_scrub()
    store.flush()
    assert svc.corrupt_found == 1
    assert store.manifest["quarantined"][0]["key"] == "full_00000008"
    assert store.latest_full()["step"] == 4   # recovery target fell back
    store.close()


def test_remote_sweep_orphans_keeps_live_generation(tmp_path):
    obj = FakeObjectStore()
    be = RemoteObjectBackend(obj, chunk_bytes=256)
    store = CheckpointStore(backend=be)
    store.save_full(4, full_state(4))
    live = set(obj.list_objects())
    # debris: a crashed upload (chunks, no index) + a stale generation
    obj.put_object("full_00000009/deadbeef.000000.chunk", b"x" * 64)
    obj.put_object("full_00000004/00000000.000000.chunk", b"y" * 64)
    removed = be.sweep_orphans(min_age_s=0)
    assert removed == 2
    assert set(obj.list_objects()) == live
    store.close()


def test_sharded_verify_and_orphan_sweep(tmp_path):
    root = str(tmp_path / "shv")
    be = ShardedBackend(root, num_shards=2, split_threshold_bytes=64)
    be.put("full_00000004", full_state(4))
    assert be.verify("full_00000004") is None
    # corrupt one shard file -> verify names the shard
    shard_file = os.path.join(root, "shard_000", "full_00000004.ckpt")
    corrupt_file_tail(shard_file)
    assert "shard" in be.verify("full_00000004")
    # orphan: shard files without a committed meta are reaped, aged
    orphan = os.path.join(root, "shard_001", "full_00000099.ckpt")
    with open(orphan, "wb") as f:
        f.write(b"RFRAME01 garbage")
    os.utime(orphan, (time.time() - 120, time.time() - 120))
    assert be.sweep_orphans(min_age_s=60) == 1
    assert not os.path.exists(orphan)
    be.close()


# --------------------------------------------------------------------------
# journal segments: multi-controller manifest
# --------------------------------------------------------------------------

def seg_tree_write(root, hosts=3, per_host=5):
    """Each host appends its own disjoint diff entries + host 0 a full."""
    journals = [SegmentedManifestJournal(root, host=f"h{i}",
                                         compact_every=10_000)
                for i in range(hosts)]
    journals[0].append("add", "fulls",
                       entry={"step": 2, "key": "full_00000002", "bytes": 1})
    step = 3
    for r in range(per_host):
        for j in journals:
            j.append("add", "diffs",
                     entry={"step": step, "key": f"diff_{step:08d}",
                            "bytes": 1, "host": j.host})
            step += 1
    return journals, step


def normalized(manifest):
    return {k: sorted((str(e) for e in v))
            for k, v in manifest.items() if v}


def test_segmented_merge_matches_single_journal(tmp_path):
    sroot = str(tmp_path / "single")
    single = ManifestJournal(sroot, compact_every=10_000)
    sjournals, step = seg_tree_write(str(tmp_path / "seg"))
    # mirror the same records through the single journal, in write order
    single.append("add", "fulls",
                  entry={"step": 2, "key": "full_00000002", "bytes": 1})
    for s in range(3, step):
        single.append("add", "diffs",
                      entry={"step": s, "key": f"diff_{s:08d}", "bytes": 1,
                             "host": f"h{(s - 3) % 3}"})
    for j in sjournals:
        j.close()
    # a fresh reader of the segmented root sees the merged view ==
    # the single journal's manifest (modulo list order, which carries
    # no chain semantics — every consumer sorts by step)
    reader = SegmentedManifestJournal(str(tmp_path / "seg"), host="reader")
    assert normalized(reader.manifest) == normalized(single.manifest)
    # and the merge (compaction) round-trips bit-identically
    reader.compact()
    reader.close()
    reader2 = SegmentedManifestJournal(str(tmp_path / "seg"), host="r2")
    assert normalized(reader2.manifest) == normalized(single.manifest)
    reader2.close()
    single.close()


def test_segmented_store_recovery_bit_identical_to_single(tmp_path):
    """Two hosts persist disjoint halves of one chain through their own
    journal segments; a fresh reader recovers byte-identical state to
    the same chain written through one journal."""
    from repro.core import recovery as recmod
    sroot, mroot = str(tmp_path / "one"), str(tmp_path / "many")
    one = make_store(sroot)
    h0 = CheckpointStore(backend=LocalFSBackend(mroot), host_id="h0")
    h1 = CheckpointStore(backend=LocalFSBackend(mroot), host_id="h1")
    one.save_full(2, full_state(2))
    h0.save_full(2, full_state(2))
    for s in range(3, 9):
        one.save_diff(s, pay(s))
        (h0 if s % 2 else h1).save_diff(s, pay(s))
    h0.close()
    h1.close()
    reader = CheckpointStore(backend=LocalFSBackend(mroot), host_id="rd")
    sa, da = recmod.load_latest_chain(one)
    sb, db = recmod.load_latest_chain(reader)
    assert int(sa["step"]) == int(sb["step"]) == 2
    np.testing.assert_array_equal(sa["params"]["g"], sb["params"]["g"])
    assert [s for s, _ in da] == [s for s, _ in db] == list(range(3, 9))
    for (_, a), (_, b) in zip(da, db):
        np.testing.assert_array_equal(a["g"], b["g"])
    reader.close()
    one.close()


@pytest.mark.parametrize("point", ["merge:premerge", "merge:snapshotted"])
def test_merge_crash_is_idempotent(tmp_path, point):
    """A crash on either side of the merge's atomic snapshot write
    loses no record and duplicates none (watermark-guarded)."""
    root = str(tmp_path / "mc")
    journals, _ = seg_tree_write(root, hosts=2, per_host=4)
    before = normalized(
        SegmentedManifestJournal(root, host="peek").manifest)

    merger = journals[0]

    def boom(p):
        if p == point:
            raise InjectedCrash(p)
    merger._crash_hook = boom
    with pytest.raises(InjectedCrash):
        merger.compact()
    merger._crash_hook = None
    for j in journals:
        j.close()
    after = SegmentedManifestJournal(root, host="after")
    assert normalized(after.manifest) == before
    after.compact()           # the re-run merge finishes the job
    after.close()
    final = SegmentedManifestJournal(root, host="final")
    assert normalized(final.manifest) == before
    final.close()


def test_service_merge_task_with_segmented_store(tmp_path):
    root = str(tmp_path / "svcmerge")
    store = make_store(root, host_id="h0")
    build_chain(store, fulls=(4, 8))
    svc = MaintenanceService(store)
    store.attach_maintenance(svc)
    svc.start()
    svc.request_merge()
    store.flush()
    assert svc.merge_runs == 1
    # post-merge: the segment was folded + truncated; a reader survives
    assert store.journal.log_bytes() == 0
    reader = CheckpointStore(backend=LocalFSBackend(root), host_id="r")
    assert [e["step"] for e in reader.manifest["fulls"]] == [4, 8]
    reader.close()
    store.close()


def test_journal_mode_switch_loses_no_records(tmp_path):
    """Unfolded records survive switching an existing store to
    --host-id segments and back (both directions fold the other
    format's log on load)."""
    root = str(tmp_path / "modes")
    # plain journal era: records land in manifest.log, never compacted
    plain = make_store(root)
    plain.save_full(4, full_state(4))
    plain.save_diff(5, pay(5))
    plain.close()
    # upgrade to segments: the plain log's records must be visible
    seg = CheckpointStore(backend=LocalFSBackend(root), host_id="h0")
    assert [e["step"] for e in seg.manifest["fulls"]] == [4]
    seg.save_diff(6, pay(6))
    seg.close()
    # downgrade back to the plain journal: segment records visible too
    back = make_store(root)
    assert sorted(e["step"] for e in back.manifest["diffs"]) == [5, 6]
    back.save_diff(7, pay(7))
    # compaction folds everything and further reloads stay complete
    back.journal.compact()
    back.close()
    final = make_store(root)
    assert sorted(e["step"] for e in final.manifest["diffs"]) == [5, 6, 7]
    assert [e["step"] for e in final.manifest["fulls"]] == [4]
    final.close()


def test_merge_lock_serializes_cross_host_compaction(tmp_path):
    root = str(tmp_path / "lock")
    journals, _ = seg_tree_write(root, hosts=2, per_host=3)
    # a live merger holds the lock: a concurrent compact skips (False)
    # and leaves every record safely in the segments
    lock = os.path.join(root, SegmentedManifestJournal.MERGE_LOCK)
    with open(lock, "w"):
        pass
    assert journals[1].compact() is False
    assert journals[1].merge_contentions == 1
    # a stale lock (dead merger) is broken and the merge proceeds
    os.utime(lock, (time.time() - 600, time.time() - 600))
    assert journals[0].compact() is True
    for j in journals:
        j.close()
    reader = SegmentedManifestJournal(root, host="r")
    assert len(reader.manifest["diffs"]) == 6
    reader.close()


def test_service_stop_then_start_resumes_journaled_work(tmp_path):
    """stop() mid-task leaves the plan journaled; the SAME service
    instance restarts cleanly (progress file reopens) and finishes."""
    root = str(tmp_path / "stopstart")
    store = make_store(root)
    build_chain(store)
    svc = MaintenanceService(store, gc_slice=2)
    store.attach_maintenance(svc)
    kill_at(svc, "gc:cursored")
    svc.start()
    svc.request_gc(2)
    wait_dead(svc)
    svc.stop()                    # closes the progress journal
    svc.crash_hook = None
    svc.start()                   # same instance: reopen + resume
    svc.drain(30.0)
    assert svc.error is None
    assert_no_leak_no_loss(store)
    assert [e["step"] for e in store.manifest["fulls"]] == [12, 16]
    store.close()


class TransientVerifyBackend(LocalFSBackend):
    """First verify() call fails like a flaky remote wire."""

    def __init__(self, root):
        super().__init__(root)
        self.flaked = 0

    def verify(self, key):
        from repro.checkpoint.remote import RetryExhaustedError
        if self.flaked == 0:
            self.flaked += 1
            raise RetryExhaustedError("injected transient exhaustion")
        return super().verify(key)


def test_transient_verify_error_does_not_poison_worker(tmp_path):
    be = TransientVerifyBackend(str(tmp_path / "flaky"))
    store = CheckpointStore(backend=be)
    build_chain(store, fulls=(4, 8))
    svc = MaintenanceService(store)
    store.attach_maintenance(svc)
    svc.start()
    svc.request_scrub()
    store.flush(timeout=30.0)      # must NOT raise: transient skipped
    assert svc.error is None and svc.running
    assert svc.scrub_transient_skips == 1
    # the remaining 7 of the chain's 8 blobs were still verified
    assert svc.scrubbed == 7
    assert store.manifest.get("quarantined", []) == []
    store.close()


def test_progress_journal_is_host_scoped(tmp_path):
    """Two hosts' services over one ckpt-dir journal progress into
    separate files — one host's idle-compaction can never truncate the
    other's in-flight plan."""
    root = str(tmp_path / "hosts")
    s0 = CheckpointStore(backend=LocalFSBackend(root), host_id="h0")
    s1 = CheckpointStore(backend=LocalFSBackend(root), host_id="h1")
    svc0 = MaintenanceService(s0)
    svc1 = MaintenanceService(s1)
    assert os.path.basename(svc0.progress.path) == "maintenance.h0.log"
    assert os.path.basename(svc1.progress.path) == "maintenance.h1.log"
    # h1 journals a plan; h0 retiring its own work must not touch it
    svc1.progress.append({"task": "gc", "id": 1, "op": "plan",
                          "doomed": [["diffs", "diff_00000001"]]})
    svc0.progress.append({"task": "gc", "id": 1, "op": "plan",
                          "doomed": []})
    svc0.progress.append({"task": "gc", "id": 1, "op": "done"})
    svc0.progress.compact_if_idle()
    assert svc1.progress.pending() != []
    s0.close()
    s1.close()


# --------------------------------------------------------------------------
# eviction policy variants
# --------------------------------------------------------------------------

def _fill(be, n=4, start=0, size=2048):
    for i in range(start, start + n):
        be.put(f"blob_{i:02d}", {"g": np.full(size, float(i), np.float32)})
    be.flush()


def test_lru_keeps_recovery_read_resident_fifo_does_not(tmp_path):
    resident = {}
    for policy in ("fifo", "lru"):
        be = MemoryTierBackend(LocalFSBackend(str(tmp_path / policy)),
                               capacity_bytes=40 * 1024, eviction=policy)
        _fill(be, 4)
        be.get("blob_00")          # recovery read refreshes recency
        _fill(be, 4, start=4)
        with be._lock:
            resident[policy] = set(be._mem)
        be.close()
    assert "blob_00" in resident["lru"]
    assert "blob_00" not in resident["fifo"]


def test_size_class_buckets_evict_bulk_before_small(tmp_path):
    be = MemoryTierBackend(LocalFSBackend(str(tmp_path / "sc")),
                           capacity_bytes=64 * 1024)
    be.put("big", {"g": np.zeros(12 * 1024, np.float32)})     # 48 KiB
    for i in range(10):
        be.put(f"small_{i}", {"g": np.full(512, float(i), np.float32)})
    be.flush()
    with be._lock:
        resident = set(be._mem)
    # the big stale blob went first; the ten small hot blobs survive
    assert "big" not in resident
    assert sum(1 for k in resident if k.startswith("small")) == 10
    assert be.stats()["resident_bytes"] <= 64 * 1024
    be.close()


@pytest.mark.parametrize("policy", ["fifo", "lru"])
def test_chain_protection_guard_unchanged_for_both_policies(tmp_path, policy):
    be = MemoryTierBackend(LocalFSBackend(str(tmp_path / f"pg_{policy}")),
                           capacity_bytes=24 * 1024, eviction=policy)
    store = CheckpointStore(backend=be)
    store.save_full(2, full_state(2))
    for s in (3, 4):
        store.save_diff(s, {"g": np.full(2048, float(s), np.float32)})
    store.save_full(5, {"params": {"g": np.full(2048, 5.0, np.float32)},
                        "step": np.int32(5)})
    store.save_diff(6, {"g": np.full(2048, 6.0, np.float32)})
    store.flush()
    with be._lock:
        resident = set(be._mem)
    assert {"full_00000005", "diff_00000006"} <= resident
    store.close()


# --------------------------------------------------------------------------
# flush(): deadline + error-surfacing contract
# --------------------------------------------------------------------------

class ExplodingDeleteBackend(LocalFSBackend):
    def delete(self, key):
        raise RuntimeError("disk on fire")


def test_store_flush_surfaces_maintenance_error(tmp_path):
    be = ExplodingDeleteBackend(str(tmp_path / "boom"))
    store = CheckpointStore(backend=be)
    build_chain(store, fulls=(4, 8))
    svc = MaintenanceService(store, gc_slice=2)
    store.attach_maintenance(svc)
    svc.start()
    svc.request_gc(1)
    with pytest.raises(CheckpointingError, match="maintenance"):
        store.flush(timeout=10.0)
    store.maintenance = None   # detach so close() doesn't re-raise
    svc.stop()
    store.backend = LocalFSBackend(str(tmp_path / "boom"))
    store.close()


def test_store_flush_times_out_instead_of_hanging(tmp_path):
    store = make_store(str(tmp_path / "hang"))
    build_chain(store, fulls=(4, 8))
    svc = MaintenanceService(store, gc_slice=1)
    store.attach_maintenance(svc)
    # never started: pending work can't drain -> bounded error, no hang
    svc.request_gc(1)
    with pytest.raises(CheckpointingError, match="not running"):
        store.flush(timeout=0.5)
    store.maintenance = None
    store.close()


# --------------------------------------------------------------------------
# LowDiff end-to-end with the service attached
# --------------------------------------------------------------------------

def test_lowdiff_with_maintenance_service(tmp_path):
    import jax
    from repro.configs import get_config
    from repro.core.lowdiff import LowDiff
    from repro.core.steps import init_state
    from repro.data.synthetic import make_batch
    from repro.models.registry import build_model

    root = str(tmp_path / "ld")
    store = make_store(root, retention_fulls=1)
    svc = MaintenanceService(store, gc_slice=4)
    store.attach_maintenance(svc)
    svc.start()
    model = build_model(get_config("qwen2-1.5b").reduced())
    ld = LowDiff(model, store, rho=0.05, lr=1e-3, full_interval=3,
                 batch_size=2, parallel_recovery=False)
    state = init_state(model, jax.random.PRNGKey(0), mode="lowdiff")
    for t in range(8):
        state, _ = ld.train_step(state, make_batch(model.cfg, 32, 2, step=t))
    ld.flush()                     # drains persist queue AND gc slices
    assert svc.gc_runs >= 1
    assert_no_leak_no_loss(store)
    rec, n = ld.recover()
    assert int(rec["step"]) == 8
    st = store.stats()
    assert st["maintenance"]["pending"] == 0
    ld.close()                     # close stops the service
    assert not svc.running
