"""Failure-simulator sanity: orderings the paper's Exp. 3/9/10 establish."""
import numpy as np

from repro.core.simulator import StrategyProfile, paper_profiles, simulate


def _run(name, profiles, mtbf, iters=20000, seeds=3):
    rs = [simulate(profiles[name], run_iters=iters, mtbf_s=mtbf, seed=s)
          for s in range(seeds)]
    return float(np.mean([r.effective_ratio for r in rs]))


def test_lowdiff_beats_baselines_under_failures():
    profiles = paper_profiles(iter_time=0.5, full_bytes=8.7e9,
                              diff_bytes=5.4e7, compress_stall=0.15)
    mtbf = 1800.0
    r = {k: _run(k, profiles, mtbf) for k in
         ["full_sync", "checkfreq", "gemini", "naive_dc", "lowdiff",
          "lowdiff_plus_s"]}
    assert r["lowdiff"] > r["checkfreq"]
    assert r["lowdiff"] > r["naive_dc"]
    assert r["lowdiff_plus_s"] >= r["gemini"] - 0.01
    assert r["lowdiff"] > 0.9


def test_effective_ratio_decreases_with_failure_rate():
    profiles = paper_profiles(iter_time=0.5, full_bytes=1.4e9,
                              diff_bytes=9.2e6)
    r_rare = _run("lowdiff", profiles, mtbf=7200)
    r_freq = _run("lowdiff", profiles, mtbf=360)
    assert r_rare > r_freq


def test_no_failures_no_waste():
    p = StrategyProfile("x", iter_time=0.1, ckpt_overhead=0.0,
                        ckpt_interval=1, restore_time=1.0)
    r = simulate(p, run_iters=1000, mtbf_s=1e12, seed=0)
    assert r.failures == 0
    assert abs(r.wasted_time) < 1e-6
