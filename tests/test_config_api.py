"""Declarative store/engine configuration API.

Covers the ISSUE-6 satellite surface:

* ``to_dict`` / ``from_dict`` round-trip stability for TierSpec,
  StoreConfig and EngineConfig;
* validation errors that name the offending field (``tiers[0].shards``
  style), so a config typo fails loudly instead of silently ignoring
  the knob;
* parity between the legacy factories (``make_store`` /
  ``make_backend`` / ``build_strategy``) and the config path — same
  backend composition, same persisted bytes, same engine class;
* the legacy factories emit ``DeprecationWarning``.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.checkpoint import make_backend, make_store
from repro.checkpoint.config import (StoreConfig, StoreConfigError,
                                     TierSpec)
from repro.configs import get_config
from repro.core.engine import EngineConfig, make_engine
from repro.launch.train import build_strategy
from repro.models.registry import build_model


def payload(seed=0, n=512):
    rng = np.random.default_rng(seed)
    return {f"w{i}": rng.standard_normal(n).astype(np.float32)
            for i in range(4)}


def tree_equal(a, b):
    return (set(a) == set(b)
            and all(np.array_equal(a[k], b[k]) for k in a))


def stack(backend):
    """Backend class names hot-to-cold, following ``.lower`` links."""
    names = []
    while backend is not None:
        names.append(type(backend).__name__)
        backend = getattr(backend, "lower", None)
    return names


# ---------------------------------------------------------------------------
# round-trip stability


def test_tierspec_roundtrip_is_minimal_and_stable():
    spec = TierSpec("remote", url="fake://b", chunk_mb=1.0,
                    capacity_mb=32.0)
    d = spec.to_dict()
    # only non-default fields serialize — configs diff cleanly
    assert d == {"kind": "remote", "url": "fake://b", "chunk_mb": 1.0,
                 "capacity_mb": 32.0}
    back = TierSpec.from_dict(d)
    assert back == spec
    assert back.to_dict() == d


def test_storeconfig_roundtrip(tmp_path):
    cfg = StoreConfig(
        str(tmp_path),
        tiers=[TierSpec("peer", replicas=2, hub="rt", simulate_peers=True),
               TierSpec("memory", capacity_mb=64.0, eviction="lru"),
               TierSpec("local")],
        fmt="frame", retention_fulls=2, host_id="hA")
    d = cfg.to_dict()
    back = StoreConfig.from_dict(d)
    assert back == cfg
    assert back.to_dict() == d


def test_engineconfig_roundtrip(tmp_path):
    cfg = EngineConfig(strategy="lowdiff_plus", persist_mode="incremental",
                       persist_threshold=0.01, fold_interval=8,
                       store=StoreConfig(str(tmp_path)))
    d = cfg.to_dict()
    back = EngineConfig.from_dict(d)
    assert back == cfg
    assert back.to_dict() == d


def test_engineconfig_roundtrip_without_store():
    cfg = EngineConfig(strategy="checkfreq", lr=0.01)
    back = EngineConfig.from_dict(cfg.to_dict())
    assert back == cfg and back.store is None


# ---------------------------------------------------------------------------
# validation errors name the offending field


@pytest.mark.parametrize("build,needle", [
    # a knob on the wrong tier kind
    (lambda: TierSpec("local", capacity_mb=64.0).validate("tiers[0]"),
     "tiers[0].capacity_mb"),
    (lambda: TierSpec("memory", shards=8).validate("tiers[1]"),
     "tiers[1].shards"),
    (lambda: TierSpec("bogus").validate("tiers[0]"), "tiers[0].kind"),
    (lambda: TierSpec.from_dict({"replicas": 2}), "tier.kind: missing"),
    (lambda: TierSpec.from_dict({"kind": "local", "nope": 1}, "tiers[0]"),
     "tiers[0].nope"),
    # store-level shape errors
    (lambda: StoreConfig("/t", tiers=[]).validate(), "tiers"),
    (lambda: StoreConfig("/t", tiers=[TierSpec("local"),
                                      TierSpec("memory")]).validate(),
     "tiers[1].kind"),        # cold tier above a hotter one
    (lambda: StoreConfig("/t", tiers=[TierSpec("peer")]).validate(),
     "tiers[0].kind"),        # peer tier cannot anchor a store
    (lambda: StoreConfig(None, tiers=[TierSpec("local")]).validate(),
     "root"),
    (lambda: StoreConfig("/t", fmt="xml").validate(), "fmt"),
    (lambda: StoreConfig("/t", retention_fulls=-1).validate(),
     "retention_fulls"),
    (lambda: StoreConfig.from_dict({"root": "/t", "surprise": 1}),
     "surprise: unknown field"),
    # engine-level
    (lambda: EngineConfig(strategy="bogus").validate(), "strategy"),
    (lambda: EngineConfig(persist_mode="patchy").validate(),
     "persist_mode"),
    (lambda: EngineConfig.from_dict({"vibe": "good"}),
     "vibe: unknown field"),
])
def test_validation_names_the_offending_field(build, needle):
    with pytest.raises(StoreConfigError) as ei:
        build()
    assert needle in str(ei.value), str(ei.value)


def test_duplicate_tier_kind_rejected():
    with pytest.raises(StoreConfigError, match="duplicate kind"):
        StoreConfig("/t", tiers=[TierSpec("memory"), TierSpec("memory"),
                                 TierSpec("local")]).validate()


# ---------------------------------------------------------------------------
# legacy-factory parity: same composition, same bytes, same recovery


LEGACY_CASES = [
    ("local", {}),
    ("sharded", {"shards": 2}),
    ("memory", {"capacity_mb": 64.0, "eviction": "lru"}),
    ("remote", {"remote_url": "fake://parity", "chunk_mb": 0.5}),
]


@pytest.mark.parametrize("backend,kw",
                         LEGACY_CASES, ids=[c[0] for c in LEGACY_CASES])
def test_make_store_parity_with_config_path(tmp_path, backend, kw):
    with pytest.warns(DeprecationWarning, match="make_store"):
        old = make_store(str(tmp_path / "old"), backend=backend, **kw)
    new = StoreConfig.from_legacy(str(tmp_path / "new"), backend=backend,
                                  **kw).build()
    try:
        assert stack(old.backend) == stack(new.backend)
        old.save_full(1, payload())
        new.save_full(1, payload())
        assert old.bytes_written == new.bytes_written
        s_old, _ = old.load_latest_state()
        s_new, _ = new.load_latest_state()
        assert tree_equal(s_old, s_new)
    finally:
        old.close()
        new.close()


def test_explicit_tiers_match_from_legacy(tmp_path):
    """Declaring the tier list by hand equals the legacy-name mapping."""
    legacy = StoreConfig.from_legacy(str(tmp_path), backend="memory",
                                     capacity_mb=32.0, eviction="lru",
                                     retention_fulls=2)
    explicit = StoreConfig(
        str(tmp_path),
        tiers=[TierSpec("memory", capacity_mb=32.0, eviction="lru"),
               TierSpec("local")],
        retention_fulls=2)
    assert legacy == explicit


def test_make_backend_remote_composition(tmp_path):
    with pytest.warns(DeprecationWarning, match="make_backend"):
        b = make_backend("remote", str(tmp_path),
                         remote_url="fake://parity-b", chunk_mb=0.5)
    try:
        # RAM tier over the chunked object backend, as before the
        # config redesign
        names = stack(b)
        assert names[0] == "MemoryTierBackend"
        assert "RemoteObjectBackend" in names[1]
    finally:
        b.close()


def test_peer_flag_prepends_peer_tier(tmp_path):
    cfg = StoreConfig.from_legacy(str(tmp_path), peers=2, peer_hub="pp",
                                  simulate_peers=True)
    assert [t.kind for t in cfg.tiers] == ["peer", "local"]
    store = cfg.build()
    try:
        assert type(store.backend).__name__ == "PeerReplicaBackend"
        store.save_full(1, payload())
        store.backend.flush()
        assert store.backend.ack_count("full_00000001") == 2
    finally:
        store.close()


# ---------------------------------------------------------------------------
# engine factory parity


@pytest.fixture(scope="module")
def model():
    return build_model(get_config("qwen2-1.5b").reduced())


@pytest.mark.parametrize("name", ["lowdiff", "lowdiff_plus", "checkfreq",
                                  "gemini", "naive_dc", "full_sync"])
def test_build_strategy_shim_matches_make_engine(tmp_path, model, name):
    s_old = StoreConfig(str(tmp_path / "old")).build()
    s_new = StoreConfig(str(tmp_path / "new")).build()
    try:
        with pytest.warns(DeprecationWarning, match="build_strategy"):
            old = build_strategy(name, model, s_old, lr=1e-3, rho=0.01,
                                 full_interval=4, batch_size=2)
        new = make_engine(EngineConfig(strategy=name, full_interval=4,
                                       batch_size=2), model, store=s_new)
        assert type(old) is type(new)
    finally:
        s_old.close()
        s_new.close()


def test_make_engine_none_strategy_returns_none(model):
    assert make_engine(EngineConfig(strategy="none"), model) is None
