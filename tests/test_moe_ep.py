"""Expert-parallel MoE (shard_map) equivalence vs the dense-dispatch
reference — run in a subprocess with 4 fake devices (device count is
locked at jax init, so the main test process stays single-device)."""
import os
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config
    from repro.models.registry import build_model
    from repro.models import moe as moe_lib
    from repro.launch.mesh import make_local_mesh
    from repro.distributed import sharding as shd

    cfg = get_config("deepseek-moe-16b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    lp = jax.tree.map(lambda x: x[0], params["layers"]["moe"])
    rng = np.random.default_rng(0)
    # large enough T to pass the EP token-count gate
    x = jnp.asarray(rng.normal(size=(8, 16, cfg.d_model)) * 0.1, jnp.float32)

    y_ref, _ = moe_lib._moe_apply_dense(lp, x, cfg)
    mesh = make_local_mesh(2, 2)
    with shd.use_mesh(mesh):
        y_ep, _ = jax.jit(lambda p, x: moe_lib._moe_apply_ep(
            p, x, cfg, shd.current(), 2))(lp, x)
        g_ep = jax.jit(jax.grad(
            lambda p, x: moe_lib._moe_apply_ep(
                p, x, cfg, shd.current(), 2)[0].sum()))(lp, x)
    g_ref = jax.grad(
        lambda p, x: moe_lib._moe_apply_dense(p, x, cfg)[0].sum())(lp, x)

    assert float(jnp.max(jnp.abs(y_ref - y_ep))) < 1e-5
    gerr = max(float(jnp.max(jnp.abs(a - b)))
               for a, b in zip(jax.tree.leaves(g_ep), jax.tree.leaves(g_ref)))
    assert gerr < 1e-5, gerr
    print("EP_OK")
""")


def test_ep_moe_matches_dense_reference():
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "EP_OK" in out.stdout
