"""Frame-format tests: npz parity, mixed-format chains, corruption.

Covers the zero-copy write path's acceptance criteria:
  * frame <-> npz round-trip parity (bf16 views, SparseGrad/QuantGrad/
    PackedDiff, registered NamedTuples, scalars, empty arrays)
  * streamed chunking reassembles bit-identically at any chunk size
  * mixed-format chain recovery: an old npz full + new frame diffs
    replays bit-identical to a pure-npz chain
  * a corrupted leaf (bad sha256) is rejected, a truncated frame is
    rejected, and the journal records the per-entry format tag
  * async snapshots materialize the same bytes as the seed's
    synchronous host_copy
"""
import json
import os

import jax
import jax.numpy as jnp
import ml_dtypes
import numpy as np
import pytest

from repro.checkpoint import io as cio
from repro.checkpoint import make_store
from repro.checkpoint.backends import LocalFSBackend, ShardedBackend
from repro.checkpoint.remote import FakeObjectStore, RemoteObjectBackend
from repro.compression.packed import PackedDiff
from repro.compression.quant import QuantGrad
from repro.compression.sparse import SparseGrad
from repro.core import recovery as rec
from repro.core.snapshot import SnapshotArena, host_copy
from repro.optim.adam import AdamState


def sample_tree(seed: int = 0):
    rng = np.random.default_rng(seed)
    return {
        "w": rng.normal(size=(48, 260)).astype(np.float32),
        "bf16": rng.normal(size=(1024,)).astype(ml_dtypes.bfloat16),
        "ints": np.arange(11, dtype=np.int32),
        "scalar": np.float32(2.5),
        "empty": np.zeros((0, 3), np.float32),
        "sparse": SparseGrad(
            values=np.float32(rng.normal(size=(4, 10))),
            indices=np.int32(rng.integers(0, 1024, size=(4, 10))),
            shape=(4096,), block=1024),
        "quant": QuantGrad(
            q=rng.integers(-127, 127, size=(2, 1024)).astype(np.int8),
            scale=np.float32(rng.random(2) + 0.1),
            shape=(2048,), block=1024),
        "packed": PackedDiff(
            q=rng.integers(-127, 127, size=(3, 10)).astype(np.int8),
            indices=np.int32(rng.integers(0, 1024, size=(3, 10))),
            scale=np.float32(rng.random((3, 1)) + 0.1),
            shape=(3072,), block=1024),
        "opt": AdamState(mu={"a": np.float32(rng.normal(size=(7,)))},
                         nu={"a": np.float32(rng.random(7))},
                         count=np.int32(3)),
        "nested": {"a": [np.float32(1.5), (2, 3)], "b": None,
                   "c": "label", "d": True},
    }


def assert_tree_identical(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        if isinstance(x, (np.ndarray, jax.Array)) or hasattr(x, "dtype"):
            x, y = np.asarray(x), np.asarray(y)
            assert x.dtype == y.dtype
            assert x.shape == y.shape
            np.testing.assert_array_equal(x, y)
        else:
            assert x == y


# --------------------------------------------------------------------------
# round-trip parity with npz
# --------------------------------------------------------------------------

def test_frame_roundtrip_matches_npz(tmp_path):
    tree = sample_tree()
    fpath = str(tmp_path / "t.ckpt")
    npath = str(tmp_path / "t.npz")
    cio.save_frame(fpath, tree)
    cio.save(npath, tree)
    via_frame = cio.load_any(fpath)
    via_npz = cio.load_any(npath)
    assert_tree_identical(tree, via_frame)
    assert_tree_identical(via_npz, via_frame)


def test_frame_mmap_and_eager_agree(tmp_path):
    tree = sample_tree(3)
    path = str(tmp_path / "t.ckpt")
    cio.save_frame(path, tree)
    lazy = cio.load_frame(path, mmap=True)
    eager = cio.load_frame(path, mmap=False, verify=True)
    assert_tree_identical(lazy, eager)
    # lazy leaves really are memory-mapped views, not materialized
    assert isinstance(lazy["w"], np.memmap)


def test_frame_dumps_loads_and_alignment():
    tree = sample_tree(1)
    blob = cio.frame_dumps(tree)
    assert cio.is_frame_bytes(blob)
    assert_tree_identical(tree, cio.frame_loads(blob, verify=True))
    # every leaf offset is 64-byte aligned (the memmap/DMA contract)
    buf = np.frombuffer(blob, np.uint8)
    header, _ = cio._parse_frame(buf, verify=True, source="<test>")
    assert all(leaf["offset"] % cio.FRAME_ALIGN == 0
               for leaf in header["leaves"])


@pytest.mark.parametrize("chunk_bytes", [37, 1 << 10, 1 << 22])
def test_frame_chunks_reassemble_bit_identical(chunk_bytes):
    tree = sample_tree(2)
    payload, extra = cio.frame_payload(tree)
    blob = cio.frame_dumps(tree)
    pieces = list(cio.frame_chunks(payload, chunk_bytes, extra))
    assert all(
        (p.nbytes if isinstance(p, np.ndarray) else len(p)) <= chunk_bytes
        for p in pieces)
    joined = b"".join(bytes(p) for p in pieces)
    assert joined == blob


def test_write_frame_streams_without_blob(tmp_path):
    """The file write path must not materialize an intermediate copy of
    the tensor bytes: the copy meter stays untouched."""
    tree = {"big": np.random.default_rng(0).normal(
        size=(256, 1024)).astype(np.float32)}
    cio.COPY_METER.reset()
    cio.save_frame(str(tmp_path / "z.ckpt"), tree)
    assert cio.COPY_METER.bytes == 0
    # the npz byte path does materialize (the blob dumps counts)
    cio.dumps(tree)
    assert cio.COPY_METER.bytes > tree["big"].nbytes


# --------------------------------------------------------------------------
# corruption rejection
# --------------------------------------------------------------------------

def test_corrupted_leaf_sha256_rejected(tmp_path):
    path = str(tmp_path / "c.ckpt")
    tree = {"w": np.arange(4096, dtype=np.float32)}
    cio.save_frame(path, tree)
    data = bytearray(open(path, "rb").read())
    data[-4] ^= 0xFF                   # flip one tensor byte
    open(path, "wb").write(bytes(data))
    with pytest.raises(cio.FrameCorruptionError, match="sha256"):
        cio.load_frame(path, verify=True)
    # lazy load without verify still opens (integrity is opt-in on the
    # local tier; the remote tier verifies per chunk)
    cio.load_frame(path, verify=False)


def test_truncated_frame_rejected(tmp_path):
    path = str(tmp_path / "t.ckpt")
    cio.save_frame(path, {"w": np.arange(1024, dtype=np.float32)})
    data = open(path, "rb").read()
    open(path, "wb").write(data[:len(data) // 2])
    with pytest.raises(cio.FrameCorruptionError, match="truncated"):
        cio.load_frame(path)
    with pytest.raises(cio.FrameCorruptionError, match="magic"):
        cio.frame_loads(b"not a frame at all")


# --------------------------------------------------------------------------
# backends + mixed-format chains
# --------------------------------------------------------------------------

def test_localfs_mixed_format_dir(tmp_path):
    """A directory holding both formats serves both transparently."""
    root = str(tmp_path / "mix")
    old = LocalFSBackend(root, fmt="npz")
    old.put("full_00000001", sample_tree(1))
    new = LocalFSBackend(root, fmt="frame")
    new.put("diff_00000002", sample_tree(2))
    assert new.keys() == ["diff_00000002", "full_00000001"]
    assert_tree_identical(sample_tree(1), new.get("full_00000001"))
    assert_tree_identical(sample_tree(2), new.get("diff_00000002"))
    new.delete("full_00000001")
    assert not new.exists("full_00000001")


def test_localfs_cross_format_reput_not_shadowed(tmp_path):
    """Re-putting a key under the other format must supersede the old
    file: a stale cross-format blob shadowing a fresh write would make
    recovery replay old bytes silently."""
    root = str(tmp_path / "rp")
    frame_be = LocalFSBackend(root, fmt="frame")
    frame_be.put("diff_00000009", {"g": np.full(64, 1.0, np.float32)})
    npz_be = LocalFSBackend(root, fmt="npz")
    npz_be.put("diff_00000009", {"g": np.full(64, 2.0, np.float32)})
    # both backends now serve the re-put bytes, and only one file lives
    np.testing.assert_array_equal(npz_be.get("diff_00000009")["g"],
                                  np.full(64, 2.0, np.float32))
    np.testing.assert_array_equal(frame_be.get("diff_00000009")["g"],
                                  np.full(64, 2.0, np.float32))
    assert not os.path.exists(os.path.join(root, "diff_00000009.ckpt"))
    # and the reverse direction
    frame_be.put("diff_00000009", {"g": np.full(64, 3.0, np.float32)})
    np.testing.assert_array_equal(npz_be.get("diff_00000009")["g"],
                                  np.full(64, 3.0, np.float32))
    assert not os.path.exists(os.path.join(root, "diff_00000009.npz"))


def test_packed_indices_narrow_on_wire(tmp_path):
    """PackedDiff indices persist as int16 (the nbytes accounting) and
    widen back to int32 on load."""
    pd = PackedDiff(
        q=np.ones((2, 10), np.int8),
        indices=np.arange(20, dtype=np.int32).reshape(2, 10) * 50,
        scale=np.ones((2, 1), np.float32), shape=(2048,), block=1024)
    path = str(tmp_path / "pd.ckpt")
    cio.save_frame(path, pd)
    header, leaves = cio.read_frame(path)
    stored = {leaf["dtype"] for leaf in header["leaves"]}
    assert np.dtype(np.int16).str in stored
    out = cio.load_frame(path)
    assert np.asarray(out.indices).dtype == np.int32
    np.testing.assert_array_equal(out.indices, pd.indices)


def test_sharded_frame_roundtrip(tmp_path):
    be = ShardedBackend(str(tmp_path / "sh"), num_shards=3,
                        split_threshold_bytes=1024, fmt="frame")
    tree = sample_tree(4)
    be.put("full_00000001", tree)
    meta = json.load(open(os.path.join(str(tmp_path / "sh"),
                                       "full_00000001.meta.json")))
    assert meta["format"] == "frame"
    assert_tree_identical(tree, be.get("full_00000001"))
    be.close()


def test_remote_frame_roundtrip_and_zero_copy():
    tree = {"big": np.random.default_rng(0).normal(
        size=(512, 1024)).astype(np.float32)}
    frame_be = RemoteObjectBackend(FakeObjectStore(), chunk_bytes=1 << 20,
                                   backoff_s=1e-4, fmt="frame")
    cio.COPY_METER.reset()
    frame_be.put("k", tree)
    frame_copies = cio.COPY_METER.bytes
    assert_tree_identical(tree, frame_be.get("k"))
    npz_be = RemoteObjectBackend(FakeObjectStore(), chunk_bytes=1 << 20,
                                 backoff_s=1e-4, fmt="npz")
    cio.COPY_METER.reset()
    npz_be.put("k", tree)
    npz_copies = cio.COPY_METER.bytes
    # npz: blob materialization + chunk re-slice = 2 full copies of the
    # tensor bytes; frame: only sub-threshold glue (here: none)
    assert npz_copies >= 2 * tree["big"].nbytes
    assert frame_copies == 0


def _build_and_recover(root, full_fmt, diff_fmt):
    """Write full@2 with one store (the "old binary"), reopen the root
    with another format for the diffs (the upgraded binary, packed
    compressor), then recover and replay."""
    rng = np.random.default_rng(0)
    params = {"w": rng.normal(size=(8, 1024)).astype(np.float32)}
    opt = AdamState(mu=jax.tree.map(lambda p: np.zeros_like(p), params),
                    nu=jax.tree.map(lambda p: np.zeros_like(p), params),
                    count=np.int32(0))
    state = {"params": params, "opt": opt, "step": np.int32(2)}
    s1 = make_store(root, fmt=full_fmt)
    s1.save_full(2, state)
    s1.close()
    s2 = make_store(root, fmt=diff_fmt)
    from repro.kernels.ops import packed_compress
    for s in (3, 4):
        g = jnp.asarray(rng.normal(size=(8, 1024)), jnp.float32)
        s2.save_diff(s, {"w": packed_compress(g, 0.01)})
    loaded, diffs = rec.load_latest_chain(s2)
    p2, o2 = rec.replay_serial(loaded["params"], loaded["opt"], diffs)
    tags = {kind: {e["format"] for e in s2.manifest[kind]}
            for kind in ("fulls", "diffs")}
    s2.close()
    return p2, o2, [s for s, _ in diffs], tags


def test_mixed_format_chain_recovery_bit_identical(tmp_path):
    """Old npz full + new frame diffs must replay to the exact bytes a
    pure-npz chain replays to."""
    p_ref, o_ref, steps_ref, _ = _build_and_recover(
        str(tmp_path / "pure"), "npz", "npz")
    p_mix, o_mix, steps_mix, tags = _build_and_recover(
        str(tmp_path / "mix"), "npz", "frame")
    assert steps_ref == steps_mix == [3, 4]
    # the journal carries the per-entry format tags
    assert tags == {"fulls": {"npz"}, "diffs": {"frame"}}
    assert_tree_identical(p_ref, p_mix)
    assert_tree_identical(o_ref.mu, o_mix.mu)
    assert_tree_identical(o_ref.nu, o_mix.nu)


# --------------------------------------------------------------------------
# end-to-end: LowDiff with the packed compressor over the frame format
# --------------------------------------------------------------------------

def test_lowdiff_packed_compressor_recovery(tmp_path):
    """Training with the fused compress-and-pack differential through
    the frame fast path recovers params/opt bit-identical to the live
    run (the differential identity the paper's exactness relies on)."""
    from repro.checkpoint.store import CheckpointStore
    from repro.configs import get_config
    from repro.core.lowdiff import LowDiff
    from repro.core.steps import init_state
    from repro.data.synthetic import make_batch
    from repro.models.registry import build_model

    model = build_model(get_config("qwen2-1.5b").reduced())
    store = CheckpointStore(
        backend=LocalFSBackend(str(tmp_path / "pk"), fmt="frame"))
    ld = LowDiff(model, store, rho=0.05, lr=1e-3, full_interval=4,
                 batch_size=2, parallel_recovery=False, compressor="packed")
    state = init_state(model, jax.random.PRNGKey(0), mode="lowdiff")
    for t in range(6):
        state, _ = ld.train_step(state, make_batch(model.cfg, 32, 2, step=t))
    ld.flush()
    recovered, n = ld.recover()
    assert n == 2                      # diffs 5,6 after the full@4
    assert int(recovered["step"]) == 6

    def close(a, b):
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
            # live vs replayed: identical math modulo XLA fusion across
            # jit boundaries (same bound the seed's recovery tests use)
            np.testing.assert_allclose(np.asarray(x, np.float32),
                                       np.asarray(y, np.float32),
                                       atol=2e-6, rtol=1e-5)

    close(state["params"], recovered["params"])
    close(state["opt"].mu, recovered["opt"].mu)
    close(state["opt"].nu, recovered["opt"].nu)
    # the persisted differentials really are wire-format PackedDiff
    reloaded = store.backend.get("batch_00000005_00000006")
    leaves = jax.tree.leaves(
        reloaded, is_leaf=lambda x: isinstance(x, PackedDiff))
    assert any(isinstance(x, PackedDiff) for x in leaves)
    ld.close()


# --------------------------------------------------------------------------
# async snapshot
# --------------------------------------------------------------------------

def test_async_snapshot_matches_host_copy():
    rng = np.random.default_rng(0)
    tree = {"w": jnp.asarray(rng.normal(size=(64, 128)), jnp.float32),
            "b": jnp.asarray(rng.normal(size=(7,)), jnp.bfloat16),
            "step": np.int32(5)}
    sync = host_copy(tree)
    arena = SnapshotArena(slots=2)
    pending = arena.snapshot_async(tree)
    out = pending.result()
    assert_tree_identical(sync, out)
    assert out["w"].__class__ is np.ndarray
    pending.release()
    assert arena.stats()["snapshots"] == 1


def test_snapshot_arena_backpressure():
    arena = SnapshotArena(slots=2)
    tree = {"x": np.ones(4, np.float32)}
    a = arena.snapshot_async(tree)
    b = arena.snapshot_async(tree)
    # both slots held: releasing one lets the next through without a
    # stall being recorded for it
    a.release()
    c = arena.snapshot_async(tree)
    b.release()
    c.release()
    st = arena.stats()
    assert st["snapshots"] == 3
    assert st["slots"] == 2
