"""End-to-end behaviour tests for the paper's system.

The detailed suites live in:
  test_arch_smoke.py  — per-architecture reduced-config smoke (fwd/train/decode)
  test_kernels.py     — Pallas kernels vs jnp oracles (+ hypothesis properties)
  test_lowdiff.py     — LowDiff/LowDiff+ end-to-end, recovery exactness
  test_simulator.py   — failure/MTBF simulator orderings
  test_roofline.py    — segment composition vs full-unroll validation

This module keeps the cross-cutting behaviours: a full train->fail->
recover->resume cycle driven through the public launcher, and the
config-optimizer end-to-end wiring.
"""
import argparse

import jax
import numpy as np
import pytest

from repro.checkpoint.store import CheckpointStore
from repro.configs import ASSIGNED_ARCHS, get_config
from repro.core.config_opt import OnlineTuner, SystemParams
from repro.core.lowdiff import LowDiff
from repro.core.steps import init_state
from repro.data.synthetic import TokenStream, make_batch
from repro.models.registry import build_model


def test_launcher_end_to_end_with_failure(tmp_path):
    """The public training driver survives an injected failure."""
    from repro.launch import train as T
    args = argparse.Namespace(
        arch="qwen2-1.5b", reduced=True, steps=12, batch=2, seq=32,
        lr=1e-3, rho=0.05, strategy="lowdiff", full_interval=5,
        batch_size=2, ckpt_dir=str(tmp_path / "ck"), clean=True,
        fail_at=8, seed=0, log_every=0)
    losses, times = T.run(args)
    assert len(losses) == 12
    assert np.isfinite(losses).all()


def test_training_is_deterministic_across_recovery(tmp_path):
    """Resume-from-recovery replays the same data and produces the same
    loss trajectory as an uninterrupted run (modulo the EF reset)."""
    cfg = get_config("stablelm-1.6b").reduced()
    model = build_model(cfg)

    def run(fail):
        store = CheckpointStore(str(tmp_path / f"d{fail}"))
        ld = LowDiff(model, store, rho=1.0, lr=1e-3, full_interval=4,
                     batch_size=1, error_feedback=False)
        state = init_state(model, jax.random.PRNGKey(0), mode="lowdiff")
        if "ef" in state:
            del state["ef"]
        stream = TokenStream(cfg, 32, 2)
        losses = []
        for t in range(10):
            state, m = ld.train_step(state, next(stream))
            losses.append(float(m["loss"]))
            if fail and t + 1 == 6:
                ld.flush()
                state, _ = ld.recover()
                stream.step = int(state["step"])
        ld.close()
        return losses

    a = run(fail=False)
    b = run(fail=True)
    np.testing.assert_allclose(a, b, rtol=1e-4)


def test_online_tuner_adapts():
    tuner = OnlineTuner(SystemParams(M=3600, W=5e9, S=1e9, R_D=0.5))
    i0, b0 = tuner.current()
    for _ in range(8):
        tuner.observe_failure_gap(200.0)   # failures now very frequent
    i1, b1 = tuner.current()
    assert i1 <= i0                        # checkpoint more often

def test_all_archs_have_configs():
    assert len(ASSIGNED_ARCHS) == 10
    for a in ASSIGNED_ARCHS:
        cfg = get_config(a)
        assert cfg.param_count() > 0
        batch = make_batch(cfg.reduced(), 16, 1)
        assert batch["tokens"].shape == (1, 16)
