"""Unified observability layer: metrics registry, span tracer, step
timeline, and the trace_report analysis tool.

Also holds the registry<->stats() sync guard: every converted
component's legacy ``stats()`` keys must be backed by instruments in
its :class:`~repro.obs.metrics.InstrumentSet` (no orphaned ad-hoc dict
keys after the migration).
"""
import gc
import json
import threading
import time

import pytest

from repro.analysis.trace_report import (attribution, category_rollup,
                                         load_chrome_trace,
                                         load_metrics_jsonl,
                                         median_step_wall, overhead_pct,
                                         slowest_spans)
from repro.obs.metrics import (Counter, Gauge, Histogram, InstrumentSet,
                               MetricsRegistry, default_buckets)
from repro.obs.timeline import STALL_CATEGORIES, StepTimeline
from repro.obs.trace import TRACER, SpanTracer, trace_span, traced


# ---------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------
class TestInstruments:
    def test_counter(self):
        c = Counter("x")
        c.add()
        c.add(4)
        assert c.value == 5
        assert c.snapshot() == {"name": "x", "type": "counter", "value": 5}
        c.reset()
        assert c.value == 0

    def test_gauge(self):
        g = Gauge("depth")
        g.set(7)
        g.add(-3)
        assert g.value == 4
        assert g.snapshot()["type"] == "gauge"

    def test_default_buckets_monotonic(self):
        b = default_buckets()
        assert b == sorted(b)
        assert b[0] == pytest.approx(1e-5)
        assert b[-1] == pytest.approx(100.0)

    def test_histogram_basic(self):
        h = Histogram("t")
        for v in (0.001, 0.002, 0.003, 0.004):
            h.observe(v)
        assert h.count == 4
        assert h.sum == pytest.approx(0.01)
        assert h.value == h.sum
        assert h.mean() == pytest.approx(0.0025)
        snap = h.snapshot()
        assert snap["min"] == pytest.approx(0.001)
        assert snap["max"] == pytest.approx(0.004)
        assert snap["p50"] <= snap["p95"] <= snap["p99"] <= 0.004 + 1e-9

    def test_histogram_empty(self):
        h = Histogram("t")
        assert h.percentile(50) == 0.0
        assert h.snapshot()["min"] is None

    def test_histogram_percentile_bounded_by_extremes(self):
        h = Histogram("t")
        for _ in range(100):
            h.observe(0.5)
        # all mass in one bucket: interpolation stays inside [min, max]
        assert 0.5 - 1e-9 <= h.percentile(50) <= 0.5 + 1e-9
        assert h.percentile(99) <= 0.5 + 1e-9

    def test_registry_weakref_gc(self):
        reg = MetricsRegistry()
        c = reg.counter("ephemeral")
        c.add(3)
        assert [m["name"] for m in reg.collect()] == ["ephemeral"]
        del c
        gc.collect()
        assert reg.collect() == []

    def test_registry_aggregates_same_name(self):
        reg = MetricsRegistry()
        a, b = reg.counter("store.bytes"), reg.counter("store.bytes")
        a.add(10)
        b.add(5)
        (snap,) = reg.collect()
        assert snap["value"] == 15
        h1, h2 = reg.histogram("lat"), reg.histogram("lat")
        h1.observe(0.1)
        h2.observe(0.3)
        merged = [m for m in reg.collect() if m["name"] == "lat"][0]
        assert merged["count"] == 2
        assert merged["sum"] == pytest.approx(0.4)

    def test_instrument_set_memoizes(self):
        reg = MetricsRegistry()
        s = InstrumentSet("q", registry=reg)
        assert s.counter("n") is s.counter("n")
        s.counter("n").add(2)
        s.histogram("wait").observe(1.0)
        assert s.keys() == ["n", "wait"]
        assert s.view() == {"n": 2, "wait": 1.0}
        assert s.counter("n").name == "q.n"


# ---------------------------------------------------------------------
# span tracer
# ---------------------------------------------------------------------
@pytest.fixture
def tracer():
    t = SpanTracer(buffer=1024, enabled=True)
    yield t


@pytest.fixture
def global_tracer():
    TRACER.clear()
    TRACER.enable(1024)
    yield TRACER
    TRACER.disable()
    TRACER.clear()


class TestTracer:
    def test_disabled_is_shared_noop(self):
        assert not TRACER.enabled
        s1 = trace_span("a", "cat", k=1)
        s2 = trace_span("b")
        assert s1 is s2  # module-level singleton: zero allocation
        with s1 as s:
            s.set(bytes=10)
        assert len(TRACER) == 0

    def test_disabled_overhead_guard(self):
        """The disabled path must stay cheap enough to sprinkle on the
        step path: 100k no-op spans well under a second even on a
        loaded CI box."""
        assert not TRACER.enabled
        t0 = time.perf_counter()
        for _ in range(100_000):
            with trace_span("hot", "pipeline"):
                pass
        assert time.perf_counter() - t0 < 1.0

    def test_ring_bound_and_drop_count(self):
        t = SpanTracer(buffer=16, enabled=True)
        for i in range(100):
            with t.span(f"s{i}"):
                pass
        assert len(t) == 16
        assert t.events_total == 100
        assert t.dropped == 84
        # ring keeps the newest spans
        assert t.events()[-1][0] == "s99"
        assert t.stats()["capacity"] == 16

    def test_span_nesting(self, tracer):
        with tracer.span("parent", "pipeline") as p:
            with tracer.span("child", "pipeline"):
                time.sleep(0.001)
        events = {e[0]: e for e in tracer.events()}
        # child commits first (exit order), interval nested in parent
        assert [e[0] for e in tracer.events()] == ["child", "parent"]
        child, parent = events["child"], events["parent"]
        assert parent[4] <= child[4] <= child[5] <= parent[5]

    def test_thread_identity(self, tracer):
        def work(n):
            with tracer.span("w", "pipeline", n=n):
                time.sleep(0.001)

        threads = [threading.Thread(target=work, args=(i,),
                                    name=f"worker-{i}") for i in range(3)]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        tids = {e[2] for e in tracer.events()}
        names = {e[3] for e in tracer.events()}
        assert len(tids) == 3
        assert names == {"worker-0", "worker-1", "worker-2"}

    def test_attrs_set_mid_span(self, tracer):
        with tracer.span("persist.batch", "persist", n=4) as sp:
            sp.set(bytes=123)
        (_, _, _, _, _, _, attrs) = tracer.events()[0]
        assert attrs == {"n": 4, "bytes": 123}

    def test_traced_decorator(self, global_tracer):
        @traced("maint.gc", "maintenance")
        def gc_slice():
            return 7

        assert gc_slice() == 7
        assert global_tracer.events()[0][:2] == ("maint.gc", "maintenance")

    def test_chrome_export_round_trip(self, global_tracer, tmp_path):
        with trace_span("ckpt.offload", "persist", step=3) as sp:
            sp.set(bytes=456)
        with trace_span("backend.put", "backend", tier="local"):
            pass
        path = str(tmp_path / "trace.json")
        n = global_tracer.export_chrome(path)
        events = load_chrome_trace(path)  # validates schema, raises on bad
        assert n == len(events)
        xs = [e for e in events if e["ph"] == "X"]
        metas = [e for e in events if e["ph"] == "M"]
        assert {e["name"] for e in xs} == {"ckpt.offload", "backend.put"}
        off = [e for e in xs if e["name"] == "ckpt.offload"][0]
        assert off["cat"] == "persist"
        assert off["args"] == {"step": 3, "bytes": 456}
        assert off["dur"] >= 0
        assert metas and metas[0]["name"] == "thread_name"
        with open(path, encoding="utf-8") as f:
            doc = json.load(f)
        assert doc["otherData"]["dropped_events"] == 0

    def test_load_chrome_trace_rejects_malformed(self, tmp_path):
        bad = tmp_path / "bad.json"
        bad.write_text(json.dumps({"events": []}))
        with pytest.raises(ValueError):
            load_chrome_trace(str(bad))
        bad.write_text(json.dumps(
            {"traceEvents": [{"name": "x", "ph": "X", "pid": 1, "tid": 1}]}))
        with pytest.raises(ValueError):  # complete event missing ts/dur
            load_chrome_trace(str(bad))

    def test_enable_resizes_ring(self):
        t = SpanTracer(buffer=8, enabled=True)
        for i in range(8):
            with t.span(f"s{i}"):
                pass
        t.enable(4)
        assert len(t) == 4  # keeps the newest 4
        assert t.events()[-1][0] == "s7"


# ---------------------------------------------------------------------
# step timeline / stall attribution
# ---------------------------------------------------------------------
class TestStepTimeline:
    def test_commit_sums_to_wall(self):
        tl = StepTimeline()
        tl.begin(1)
        tl.charge("queue_backpressure", 0.010)
        tl.charge("snapshot_stall", 0.005)
        rec = tl.commit(1, 0.100)
        assert rec["compute"] == pytest.approx(0.085)
        total = rec["compute"] + sum(rec.get(c, 0.0)
                                     for c in STALL_CATEGORIES)
        assert total == pytest.approx(rec["wall"])

    def test_overcharge_clamps_compute(self):
        tl = StepTimeline()
        tl.begin(1)
        tl.charge("flush_stall", 0.5)
        rec = tl.commit(1, 0.1)
        assert rec["compute"] == 0.0

    def test_charge_outside_window_dropped(self):
        tl = StepTimeline()
        tl.charge("queue_backpressure", 1.0)  # no open step
        tl.begin(1)
        rec = tl.commit(1, 0.1)
        assert "queue_backpressure" not in rec
        assert rec["compute"] == pytest.approx(0.1)

    def test_event_out_of_step(self):
        tl = StepTimeline()
        tl.event("recovery", 0.25, step=7)
        (rec,) = tl.records()
        assert rec["out_of_step"] and rec["recovery"] == 0.25
        assert rec["compute"] == 0.0

    def test_event_inside_window_redirects(self):
        tl = StepTimeline()
        tl.begin(2)
        tl.event("flush_stall", 0.02)
        rec = tl.commit(2, 0.1)
        assert rec["flush_stall"] == pytest.approx(0.02)
        assert not rec.get("out_of_step")
        assert len(tl.records()) == 1

    def test_stall_fraction_excludes_out_of_step(self):
        tl = StepTimeline()
        for s in range(4):
            tl.begin(s)
            tl.charge("queue_backpressure", 0.05)
            tl.commit(s, 0.1)
        tl.event("recovery", 100.0)  # must not pollute the signal
        assert tl.stall_fraction() == pytest.approx(0.5)

    def test_totals_and_stats(self):
        tl = StepTimeline()
        tl.begin(1)
        tl.charge("snapshot_stall", 0.03)
        tl.commit(1, 0.1)
        tl.event("flush_stall", 0.2)
        t = tl.totals()
        assert t["wall"] == pytest.approx(0.3)
        attributed = sum(t[c] for c in ("compute",) + STALL_CATEGORIES)
        assert attributed == pytest.approx(t["wall"])
        assert tl.stats()["steps"] == 1

    def test_write_jsonl_round_trip(self, tmp_path):
        tl = StepTimeline()
        tl.begin(1)
        tl.commit(1, 0.1)
        tl.event("recovery", 0.2)
        path = str(tmp_path / "m.jsonl")
        n = tl.write_jsonl(path, extra=[
            {"kind": "metric", "name": "store.writes", "type": "counter",
             "value": 3}])
        assert n == 3
        steps, metrics = load_metrics_jsonl(path)
        assert len(steps) == 2 and len(metrics) == 1
        assert metrics[0]["name"] == "store.writes"

    def test_bounded(self):
        tl = StepTimeline(maxlen=8)
        for s in range(50):
            tl.begin(s)
            tl.commit(s, 0.01)
        assert len(tl.records()) == 8
        assert tl.steps_total == 50


# ---------------------------------------------------------------------
# trace_report analyses
# ---------------------------------------------------------------------
class TestTraceReport:
    STEPS = [
        {"kind": "step", "step": 1, "wall": 0.10, "compute": 0.08,
         "queue_backpressure": 0.02},
        {"kind": "step", "step": 2, "wall": 0.12, "compute": 0.12},
        {"kind": "step", "step": None, "wall": 0.30, "compute": 0.0,
         "recovery": 0.30, "out_of_step": True},
    ]

    def test_attribution_fraction(self):
        tot = attribution(self.STEPS)
        assert tot["wall"] == pytest.approx(0.52)
        assert tot["attributed_fraction"] == pytest.approx(1.0)
        assert tot["recovery"] == pytest.approx(0.30)

    def test_median_excludes_out_of_step(self):
        assert median_step_wall(self.STEPS) == pytest.approx(0.11)

    def test_overhead_pct(self):
        base = [{"wall": 0.10, "compute": 0.10}]
        cur = [{"wall": 0.104, "compute": 0.104}]
        assert overhead_pct(cur, base) == pytest.approx(4.0)
        assert overhead_pct(cur, []) == 0.0

    def test_span_helpers(self):
        evs = [
            {"name": "a", "ph": "X", "cat": "persist", "pid": 1, "tid": 1,
             "ts": 0, "dur": 500.0},
            {"name": "b", "ph": "X", "cat": "persist", "pid": 1, "tid": 1,
             "ts": 0, "dur": 1500.0},
            {"name": "thread_name", "ph": "M", "pid": 1, "tid": 1,
             "args": {"name": "MainThread"}},
        ]
        assert [e["name"] for e in slowest_spans(evs, 1)] == ["b"]
        roll = category_rollup(evs)
        assert roll == {"persist": {"count": 2, "total_ms": 2.0}}


# ---------------------------------------------------------------------
# registry <-> stats() sync guard (no orphaned ad-hoc dict keys)
# ---------------------------------------------------------------------
class TestStatsSync:
    def _assert_backed(self, obj, derived=()):
        """Every legacy KEYS entry reads through an instrument, and the
        component's stats() numeric surface is KEYS + declared derived
        keys — nothing hand-rolled survives outside the registry."""
        inst_keys = set(obj.instruments().keys())
        for k in obj.KEYS:
            assert k in inst_keys, f"{k} not backed by an instrument"
            getattr(obj, k)  # legacy attribute surface still reads

    def test_copy_meter(self):
        from repro.checkpoint.io import CopyMeter
        m = CopyMeter()
        m.add(10)
        m.add_h2d(20)
        m.add_d2h(30, wait_s=0.01, span_s=0.02)
        self._assert_backed(m)
        s = m.stats()
        assert set(s) == set(m.KEYS) | {"d2h_overlap_ratio"}
        assert s["bytes"] == 10 and s["h2d_bytes"] == 20
        assert s["d2h_bytes"] == 30
        assert s["d2h_wait_s"] == pytest.approx(0.01)
        m.reset()
        assert m.stats()["bytes"] == 0

    def test_quant_meter(self):
        from repro.compression.quant_span import QuantMeter
        m = QuantMeter()
        m.add_encode(0.01, 4096, 1024)
        m.add_decode(0.002)
        self._assert_backed(m)
        s = m.stats()
        assert set(s) == set(m.KEYS) | {"ratio"}
        assert s["bytes_in"] == 4096 and s["bytes_out"] == 1024
        assert s["ratio"] == pytest.approx(4.0)
        assert s["encode_s"] == pytest.approx(0.01)
        assert s["decode_s"] == pytest.approx(0.002)
        m.reset()
        assert m.stats()["bytes_in"] == 0 and m.stats()["ratio"] is None

    def test_reusing_queue(self):
        from repro.core.reusing_queue import ReusingQueue
        q = ReusingQueue(maxsize=2)
        blocked = q.put(1, "a")
        assert isinstance(blocked, float) and blocked >= 0.0
        assert q.get(timeout=1.0) == (1, "a")
        q.close()
        self._assert_backed(q)
        s = q.stats()
        assert set(s) == set(q.KEYS) | {"consumer_error"}
        assert s["enqueued"] == 1

    def test_snapshot_arena(self):
        from repro.core.snapshot import SnapshotArena
        a = SnapshotArena(slots=2)
        self._assert_backed(a)
        assert set(a.stats()) == {"slots"} | set(a.KEYS)

    def test_store(self, tmp_path):
        from repro.checkpoint.store import CheckpointStore
        store = CheckpointStore(str(tmp_path))
        try:
            inst = set(store.instruments().keys())
            # every counter the old stats() dict hand-rolled
            assert {"bytes_written", "writes", "gc_deleted", "quarantined",
                    "folds", "fold_bytes", "folded_patches",
                    "max_amplification", "write_time_s"} <= inst
            assert store.bytes_written == 0 and store.writes == 0
        finally:
            store.close()

    def test_remote_backend(self):
        from repro.checkpoint.remote import (FakeObjectStore,
                                             RemoteObjectBackend)
        b = RemoteObjectBackend(FakeObjectStore())
        b.put("k0", {"a": 1})
        self._assert_backed(b)
        assert b.puts == 1
        assert b.stats()["puts"] == 1

    def test_global_instances_registered(self):
        """The process-global meter aggregates into the default
        registry under its prefix."""
        from repro.checkpoint.io import COPY_METER
        from repro.compression.quant_span import QUANT_METER
        from repro.obs.metrics import REGISTRY
        names = {m["name"] for m in REGISTRY.collect()}
        assert any(n.startswith("copy_meter.") for n in names)
        assert COPY_METER.instruments().get("bytes") is not None
        assert {"quant.encode_s", "quant.decode_s", "quant.bytes_in",
                "quant.bytes_out"} <= names
        assert QUANT_METER.instruments().get("encode_s") is not None
