"""Device-resident recovery fast path: fused decompress-and-apply
kernel parity, device-replay == serial-replay bit-identity (including
through every storage backend), chain-cut semantics on corrupt
payloads, and the overlapped per-shard snapshot DMA."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import StoreConfig, make_store
from repro.checkpoint.io import COPY_METER
from repro.checkpoint.remote import FakeObjectStore, RemoteObjectBackend
from repro.checkpoint.store import CheckpointStore
from repro.compression.packed import PackedDiff
from repro.compression.quant import QuantGrad, quant_compress
from repro.compression.sparse import SparseGrad, compress_tree
from repro.core import recovery as rec
from repro.core.snapshot import (ShardedPendingSnapshot, SnapshotArena,
                                 _partition_leaves, host_copy)
from repro.kernels import ops
from repro.optim.adam import AdamState, adam_update

HYPER = dict(lr=1e-3, b1=0.9, b2=0.999, eps=1e-8)


def _grad(rng, shape, dtype):
    return jnp.asarray(rng.standard_normal(shape), dtype)


def _compress(kind, g, rho=0.05, block=256):
    if kind == "topk":
        return ops.topk_compress(g, rho, block=block)
    if kind == "packed":
        return ops.packed_compress(g, rho, block=block)
    return quant_compress(g, block=block)


def _state(rng, shape, dtype):
    p = _grad(rng, shape, dtype)
    mu = jnp.asarray(rng.standard_normal(shape), jnp.float32) * 0.1
    nu = jnp.abs(jnp.asarray(rng.standard_normal(shape), jnp.float32)) * 0.01
    return p, mu, nu


def _bits(*arrays):
    """f32/bf16-safe bit views for exact comparison."""
    return [np.asarray(a).view(np.uint8) for a in arrays]


# --------------------------------------------------------------------------
# kernel parity: pallas interpret mode vs pure-jnp oracles, bit-exact
# --------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("kind", ["topk", "packed", "quant8"])
@pytest.mark.parametrize("shape", [(2048,),    # 8 blocks, exact fit
                                   (33, 77),   # odd tail, nb % 8 != 0
                                   (5,)])      # single partial block
def test_fused_apply_parity(dtype, kind, shape):
    rng = np.random.default_rng(hash((kind, shape)) % 2**32)
    p, mu, nu = _state(rng, shape, dtype)
    payload = _compress(kind, _grad(rng, shape, dtype))
    hyper = ops.adam_hyper_traced(count=3, **HYPER)
    kernel = ops.fused_decode_apply(payload, p, mu, nu, hyper,
                                    use_pallas=True)
    oracle = ops.fused_decode_apply(payload, p, mu, nu, hyper,
                                    use_pallas=False)
    for a, b in zip(_bits(*kernel), _bits(*oracle)):
        np.testing.assert_array_equal(a, b)
    assert kernel[0].dtype == dtype
    assert kernel[1].dtype == kernel[2].dtype == jnp.float32


@pytest.mark.parametrize("kind", ["topk", "packed", "quant8"])
def test_fused_apply_matches_decompress_then_adam(kind):
    """The fused kernel == host decompress + the eager optimizer, to
    float tolerance (bit-identity holds within jit, not across the
    jit/eager boundary — XLA contracts the moment update into an fma)."""
    rng = np.random.default_rng(7)
    shape = (999,)
    p, mu, nu = _state(rng, shape, jnp.float32)
    payload = _compress(kind, _grad(rng, shape, jnp.float32))
    hyper = ops.adam_hyper_traced(count=1, **HYPER)
    p2, mu2, nu2 = ops.fused_decode_apply(payload, p, mu, nu, hyper,
                                          use_pallas=True)
    ep, est = adam_update({"w": p}, {"w": payload.dense()},
                          AdamState({"w": mu}, {"w": nu},
                                    jnp.zeros((), jnp.int32)), **HYPER)
    np.testing.assert_allclose(np.asarray(p2), np.asarray(ep["w"]),
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(mu2), np.asarray(est.mu["w"]),
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(nu2), np.asarray(est.nu["w"]),
                               atol=1e-6)


def test_fused_apply_empty_k():
    """k == 0 wire rows (hand-built: ``k_for`` never emits 0) decode to
    a zero gradient — pallas and oracle paths agree bitwise and match
    the dense zero-gradient update."""
    rng = np.random.default_rng(3)
    p, mu, nu = _state(rng, (100,), jnp.float32)
    hyper = ops.adam_hyper_traced(count=1, **HYPER)
    empty = [
        SparseGrad(jnp.zeros((1, 0), jnp.float32),
                   jnp.zeros((1, 0), jnp.int32), (100,), 1024),
        PackedDiff(jnp.zeros((1, 0), jnp.int8),
                   jnp.zeros((1, 0), jnp.int32),
                   jnp.zeros((1, 1), jnp.float32), (100,), 1024),
    ]
    want = None
    for payload in empty:
        got = {up: ops.fused_decode_apply(payload, p, mu, nu, hyper,
                                          use_pallas=up)
               for up in (True, False)}
        for a, b in zip(_bits(*got[True]), _bits(*got[False])):
            np.testing.assert_array_equal(a, b)
        zero = ops.fused_adam_update(p, jnp.zeros_like(p), mu, nu, hyper,
                                     use_pallas=False)
        np.testing.assert_allclose(np.asarray(got[True][0]),
                                   np.asarray(zero[0]), atol=1e-6)
        if want is not None:    # both container kinds land on one result
            for a, b in zip(_bits(*got[True]), want):
                np.testing.assert_array_equal(a, b)
        want = _bits(*got[True])


# --------------------------------------------------------------------------
# replay_device == replay_serial, bit-identical
# --------------------------------------------------------------------------

def _tree_state(rng, dtype=jnp.float32):
    shapes = {"wq": (48, 64), "wk": (999,), "b": (7,)}
    params = {k: _grad(rng, s, dtype) for k, s in shapes.items()}
    mu = {k: jnp.zeros(s, jnp.float32) for k, s in shapes.items()}
    nu = {k: jnp.zeros(s, jnp.float32) for k, s in shapes.items()}
    return params, AdamState(mu, nu, jnp.zeros((), jnp.int32))


def _chain(rng, params, kind, n, numpy_leaves=False):
    diffs = []
    for i in range(n):
        payload = jax.tree.map(
            lambda p: _compress(kind, _grad(rng, p.shape, jnp.float32)),
            params)
        if numpy_leaves:        # the form payloads take off storage
            payload = jax.tree.map(np.asarray, payload)
        diffs.append((i + 1, payload))
    return diffs


def assert_replay_bit_identical(p_a, o_a, p_b, o_b, msg=""):
    for la, lb in zip(jax.tree.leaves(p_a), jax.tree.leaves(p_b)):
        np.testing.assert_array_equal(*_bits(la, lb), err_msg=msg)
    for la, lb in zip(jax.tree.leaves((o_a.mu, o_a.nu)),
                      jax.tree.leaves((o_b.mu, o_b.nu))):
        np.testing.assert_array_equal(*_bits(la, lb), err_msg=msg)
    assert int(o_a.count) == int(o_b.count), msg


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("kind", ["topk", "packed", "quant8"])
@pytest.mark.parametrize("window", [None, 3])
def test_replay_device_bit_identical_to_serial(kind, window, dtype):
    rng = np.random.default_rng(11)
    params, opt = _tree_state(rng, dtype)
    diffs = _chain(rng, params, kind, 7, numpy_leaves=True)
    ps, os_ = rec.replay_serial(params, opt, diffs, **HYPER)
    pd, od, n = rec.replay_device(params, opt, diffs, window=window,
                                  **HYPER)
    assert n == len(diffs)
    assert_replay_bit_identical(ps, os_, pd, od,
                                f"{kind} window={window} {dtype}")


def test_replay_device_meters_h2d_and_empty_chain():
    rng = np.random.default_rng(12)
    params, opt = _tree_state(rng)
    p0, o0, n0 = rec.replay_device(params, opt, [])
    assert n0 == 0 and p0 is params and o0 is opt
    diffs = _chain(rng, params, "topk", 4)
    COPY_METER.reset()
    _, _, n = rec.replay_device(params, opt, diffs, window=2)
    assert n == 4
    s = COPY_METER.stats()
    # staged bytes == the containers' child arrays as uploaded (full
    # flatten — containers are pytree nodes whose children are arrays)
    wire = 4 * sum(np.asarray(l).nbytes
                   for l in jax.tree.leaves(diffs[0][1]))
    assert s["h2d_events"] == 2          # one per window
    assert s["h2d_bytes"] == wire
    # the compressed upload is a fraction of what the dense host path
    # would have shipped (rho ~ 5% of fp32 leaves)
    dense = 4 * sum(l.size * 4 for l in jax.tree.leaves(params))
    assert s["h2d_bytes"] < dense // 4
    COPY_METER.reset()


# --------------------------------------------------------------------------
# chain-cut semantics on corrupt payloads (host and device paths)
# --------------------------------------------------------------------------

def _corrupt(payload):
    """Row-truncate one container: its block-row count no longer covers
    the dense shape it claims — exactly what a torn write produces."""
    def cut(leaf):
        if isinstance(leaf, SparseGrad):
            return SparseGrad(leaf.values[:-1], leaf.indices[:-1],
                              leaf.shape, leaf.block)
        return leaf
    return jax.tree.map(cut, payload, is_leaf=rec._is_compressed)


@pytest.mark.parametrize("bad_at", [0, 2, 5])
def test_replay_cuts_chain_at_corrupt_diff(bad_at):
    rng = np.random.default_rng(13)
    params, opt = _tree_state(rng)
    diffs = _chain(rng, params, "topk", 6)
    diffs[bad_at] = (diffs[bad_at][0], _corrupt(diffs[bad_at][1]))
    for fn in (rec.replay_parallel, rec.replay_device):
        p, o, n = fn(params, opt, diffs, window=2, **HYPER)
        assert n == bad_at, fn.__name__
        assert int(o.count) == bad_at, fn.__name__
    # the replayed prefix is the serial replay of the clean diffs
    ps, os_ = rec.replay_serial(params, opt, diffs[:bad_at], **HYPER)
    pd, od, _ = rec.replay_device(params, opt, diffs, window=2, **HYPER)
    assert_replay_bit_identical(ps, os_, pd, od, f"prefix bad_at={bad_at}")


def test_stage_window_rejects_structure_change():
    rng = np.random.default_rng(14)
    params, opt = _tree_state(rng)
    diffs = _chain(rng, params, "topk", 3)
    # diff 1 switches container type mid-chain (mixed compressor bug)
    diffs[1] = (2, jax.tree.map(
        lambda p: quant_compress(_grad(rng, p.shape, jnp.float32)), params))
    _, _, n = rec.replay_device(params, opt, diffs, **HYPER)
    assert n == 1


# --------------------------------------------------------------------------
# storage round-trip: device replay == serial replay on all 5 backends
# --------------------------------------------------------------------------

def mk_backend_store(tmp_path, kind):
    root = str(tmp_path / kind)
    if kind == "local":
        return make_store(root)
    if kind == "sharded":
        return make_store(root, backend="sharded", shards=3)
    if kind == "memory":
        return make_store(root, backend="memory")
    if kind == "remote":
        be = RemoteObjectBackend(FakeObjectStore(), chunk_bytes=4096,
                                 journal_root=root)
        return CheckpointStore(backend=be)
    if kind == "peer":
        cfg = StoreConfig.from_legacy(
            root, peers=2, peer_hub=f"dr_{os.path.basename(str(tmp_path))}",
            simulate_peers=True)
        return cfg.build()
    raise AssertionError(kind)


@pytest.mark.parametrize("kind", ["local", "sharded", "memory",
                                  "remote", "peer"])
def test_device_replay_bit_identical_across_backends(tmp_path, kind):
    rng = np.random.default_rng(17)
    params, opt = _tree_state(rng)
    store = mk_backend_store(tmp_path, kind)
    try:
        # one chain per compressor, at disjoint step ranges (10*ci + 1..2)
        for ci, comp in enumerate(("topk", "packed", "quant8")):
            base = 10 * ci
            for step, payload in _chain(rng, params, comp, 2):
                store.save_diff(base + step, payload)
            got = rec.contiguous_prefix(
                base, [(s, p) for s, p in store.diffs_after(base)
                       if s <= base + 2])
            assert len(got) == 2
            ps, os_ = rec.replay_serial(params, opt, got, **HYPER)
            pd, od, n = rec.replay_device(params, opt, got, **HYPER)
            assert n == 2
            assert_replay_bit_identical(ps, os_, pd, od, f"{kind}/{comp}")
    finally:
        store.close()


# --------------------------------------------------------------------------
# overlapped per-shard snapshot DMA
# --------------------------------------------------------------------------

def test_partition_leaves():
    assert _partition_leaves([], 4) == []
    assert _partition_leaves([10], 4) == [[0]]
    groups = _partition_leaves([100, 100, 100, 100], 2)
    assert groups == [[0, 1], [2, 3]]
    # contiguous cover, order preserved, never more than `shards`
    sizes = [7, 1, 900, 30, 30, 500, 2]
    groups = _partition_leaves(sizes, 3)
    assert [i for g in groups for i in g] == list(range(len(sizes)))
    assert 1 <= len(groups) <= 3
    # zero-byte leaves still partition (weight fallback)
    assert [i for g in _partition_leaves([0, 0, 0], 2) for i in g] == [0, 1, 2]


def test_sharded_snapshot_matches_host_copy():
    rng = np.random.default_rng(19)
    tree = {"a": jnp.asarray(rng.standard_normal((64, 32)), jnp.float32),
            "b": [jnp.asarray(rng.standard_normal(17), jnp.float32),
                  np.float32(3.0)]}
    want = host_copy(tree)
    COPY_METER.reset()
    ps = ShardedPendingSnapshot(tree, shards=3)
    assert 1 <= ps.shards <= 3
    got = ps.result()
    assert got is ps.result()            # cached
    for a, b in zip(jax.tree.leaves(want), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    s = COPY_METER.stats()
    nb = sum(np.asarray(l).nbytes for l in jax.tree.leaves(want))
    assert s["d2h_bytes"] == nb
    assert s["d2h_events"] == 1
    assert s["d2h_overlap_ratio"] is not None
    assert 0.0 <= s["d2h_overlap_ratio"] <= 1.0
    ps.release()
    COPY_METER.reset()


def test_arena_sharded_permits():
    arena = SnapshotArena(slots=2)
    tree = {"w": jnp.ones((8, 8))}
    a = arena.snapshot_sharded_async(tree, shards=2)
    b = arena.snapshot_sharded_async(tree, shards=2)
    assert arena.stats()["snapshots"] == 2 and arena.stats()["stalls"] == 0
    a.release()
    c = arena.snapshot_sharded_async(tree)
    assert arena.stats()["stalls"] == 0       # slot was free
    b.release()
    c.release()


def test_copy_meter_channels():
    COPY_METER.reset()
    COPY_METER.add_h2d(100)
    COPY_METER.add_d2h(50, wait_s=0.25, span_s=1.0)
    s = COPY_METER.stats()
    assert s["h2d_bytes"] == 100 and s["h2d_events"] == 1
    assert s["d2h_bytes"] == 50 and s["d2h_events"] == 1
    assert s["d2h_overlap_ratio"] == pytest.approx(0.75)
    COPY_METER.reset()
    assert COPY_METER.d2h_overlap_ratio() is None
    assert COPY_METER.stats()["h2d_bytes"] == 0
