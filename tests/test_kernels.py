"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles.

Kernels execute in interpret mode on CPU — the exact TPU program body.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:  # optional property-testing dep; never hard-fail collection
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.compression import sparse as csp
from repro.kernels import ops as kops
from repro.kernels import ref as kref

SHAPES = [(1024,), (8, 1024), (33, 700), (5, 3, 257), (4096,), (1, 1)]
DTYPES = [jnp.float32, jnp.bfloat16]


def _rand(shape, dtype, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape), dtype)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_topk_kernel_matches_ref(shape, dtype):
    x = _rand(shape, dtype)
    sg_k = kops.topk_compress(x, 0.05, use_pallas=True)
    sg_r = kops.topk_compress(x, 0.05, use_pallas=False)
    # compare decompressed tensors (index order within a block may differ)
    d_k = kops.topk_decompress(sg_k, use_pallas=True)
    d_r = kops.topk_decompress(sg_r, use_pallas=False)
    np.testing.assert_allclose(np.asarray(d_k, np.float32),
                               np.asarray(d_r, np.float32), atol=1e-6)
    # and against the compression-library reference implementation
    d_lib = csp.topk_decompress(csp.topk_compress(x, 0.05))
    np.testing.assert_allclose(np.asarray(d_k, np.float32),
                               np.asarray(d_lib, np.float32), atol=1e-6)


@pytest.mark.parametrize("rho", [0.001, 0.01, 0.1, 1.0])
def test_topk_kernel_rho_sweep(rho):
    x = _rand((16, 1024), jnp.float32, seed=3)
    d_k = kops.topk_decompress(kops.topk_compress(x, rho, use_pallas=True))
    d_r = csp.topk_decompress(csp.topk_compress(x, rho))
    np.testing.assert_allclose(np.asarray(d_k), np.asarray(d_r), atol=1e-6)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_quant_kernel_matches_ref(shape, dtype):
    x = _rand(shape, dtype, seed=1)
    q_k, s_k = kops.quant_compress(x, use_pallas=True)
    q_r, s_r = kops.quant_compress(x, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(q_k), np.asarray(q_r))
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r), rtol=1e-6)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_pack_kernel_matches_ref(shape, dtype):
    """Fused compress-and-pack (top-k + int8 quantize + wire pack) vs
    the pure-jnp oracle, compared after decompression (index order
    within a block may differ between selection algorithms)."""
    x = _rand(shape, dtype, seed=6)
    d_k = kops.packed_decompress(kops.packed_compress(x, 0.05,
                                                      use_pallas=True),
                                 use_pallas=True)
    d_r = kops.packed_decompress(kops.packed_compress(x, 0.05,
                                                      use_pallas=False),
                                 use_pallas=False)
    np.testing.assert_allclose(np.asarray(d_k, np.float32),
                               np.asarray(d_r, np.float32), atol=1e-6)


def test_pack_kernel_quantization_matches_composition():
    """The fusion must equal the two-stage composition: top-k select
    then int8 quantization of the selected values (same scale rule)."""
    x = _rand((16, 1024), jnp.float32, seed=9)
    pd = kops.packed_compress(x, 0.01, use_pallas=True)
    sg = kops.topk_compress(x, 0.01, use_pallas=True)
    # same positions selected
    np.testing.assert_array_equal(np.sort(np.asarray(pd.indices), axis=1),
                                  np.sort(np.asarray(sg.indices), axis=1))
    # scale = absmax(selected)/127; absmax is the first top-k pick
    vals = np.asarray(sg.values, np.float32)
    expect_scale = np.maximum(np.abs(vals).max(axis=1, keepdims=True) / 127.0,
                              1e-12)
    np.testing.assert_allclose(np.asarray(pd.scale), expect_scale, rtol=1e-6)
    # dequantized values match within half a quantization step
    q = np.asarray(pd.q, np.float32) * np.asarray(pd.scale)
    np.testing.assert_allclose(np.sort(q, axis=1), np.sort(vals, axis=1),
                               atol=float(expect_scale.max()) * 0.5 + 1e-7)


def test_packed_wire_sizes():
    """PackedDiff is the wire format: int8 values + per-block scale —
    ~4x smaller than the f32 SparseGrad at the same rho."""
    x = _rand((64, 1024), jnp.float32, seed=10)
    pd = kops.packed_compress(x, 0.01)
    sg = kops.topk_compress(x, 0.01)
    assert np.asarray(pd.q).dtype == np.int8
    assert pd.nbytes < sg.nbytes


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_fused_adam_matches_ref(shape, dtype):
    p = _rand(shape, dtype, seed=2)
    g = _rand(shape, jnp.float32, seed=3)
    mu = _rand(shape, jnp.float32, seed=4) * 0.1
    nu = jnp.abs(_rand(shape, jnp.float32, seed=5)) * 0.1
    hyper = kops.adam_hyper(1e-3, 0.9, 0.999, 1e-8, 3)
    outs_k = kops.fused_adam_update(p, g, mu, nu, hyper, use_pallas=True)
    outs_r = kops.fused_adam_update(p, g, mu, nu, hyper, use_pallas=False)
    for a, b in zip(outs_k, outs_r):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=1e-6, rtol=1e-5)


def test_fused_adam_matches_optimizer():
    """Kernel result == pytree Adam (the system's optimizer)."""
    from repro.optim.adam import AdamState, adam_init, adam_update
    p = {"w": _rand((600,), jnp.float32, seed=7)}
    g = {"w": _rand((600,), jnp.float32, seed=8)}
    st = adam_init(p)
    p2, st2 = adam_update(p, g, st, lr=1e-3)
    hyper = kops.adam_hyper(1e-3, 0.9, 0.999, 1e-8, 1)
    pk, muk, nuk = kops.fused_adam_update(p["w"], g["w"], st.mu["w"],
                                          st.nu["w"], hyper)
    np.testing.assert_allclose(np.asarray(pk), np.asarray(p2["w"]), atol=1e-6)
    np.testing.assert_allclose(np.asarray(muk), np.asarray(st2.mu["w"]), atol=1e-6)
    np.testing.assert_allclose(np.asarray(nuk), np.asarray(st2.nu["w"]), atol=1e-6)


# ---------------------------- property tests -------------------------------

def _hyp(**kw):
    """@given-or-parametrize: hypothesis strategies when the optional
    dep is installed, a fixed case sweep otherwise. Each kwarg maps a
    parameter name to ((strategy_name, *args), fallback_values)."""
    def deco(fn):
        if HAVE_HYPOTHESIS:
            strategies = {k: getattr(st, spec[0])(*spec[1:])
                          for k, (spec, _) in kw.items()}
            return settings(max_examples=25, deadline=None)(
                given(**strategies)(fn))
        names = ",".join(kw)
        cases = list(zip(*(fb for _, fb in kw.values())))
        return pytest.mark.parametrize(names, cases)(fn)
    return deco


@_hyp(n=(("integers", 1, 5000), [1, 37, 1024, 5000]),
      rho=(("floats", 0.001, 0.5), [0.5, 0.01, 0.1, 0.001]),
      seed=(("integers", 0, 99), [0, 1, 2, 3]))
def test_topk_roundtrip_preserves_selected(n, rho, seed):
    """decompress(compress(x)) keeps selected entries exactly and zeroes
    the rest; selected magnitudes dominate unselected ones per block."""
    x = np.asarray(_rand((n,), jnp.float32, seed=seed))
    sg = csp.topk_compress(jnp.asarray(x), rho)
    d = np.asarray(csp.topk_decompress(sg))
    nz = d != 0
    np.testing.assert_allclose(d[nz], x[nz], atol=0)
    # block-level dominance
    block = sg.block
    pad = (-n) % block
    xp = np.pad(x, (0, pad)).reshape(-1, block)
    dp = np.pad(d, (0, pad)).reshape(-1, block)
    for xrow, drow in zip(xp, dp):
        kept = drow != 0
        if kept.any() and (~kept).any():
            assert np.abs(xrow[kept]).min() >= np.abs(xrow[~kept]).max() - 1e-6


@_hyp(n=(("integers", 1, 4000), [1, 65, 1023, 4000]),
      seed=(("integers", 0, 99), [0, 1, 2, 3]))
def test_quant_roundtrip_error_bound(n, seed):
    """|dequant(quant(x)) - x| <= scale/2 per block (absmax int8)."""
    x = np.asarray(_rand((n,), jnp.float32, seed=seed))
    qg = __import__("repro.compression.quant", fromlist=["quant_compress"])
    q = qg.quant_compress(jnp.asarray(x))
    d = np.asarray(qg.quant_decompress(q))
    scales = np.asarray(q.scale)
    pad = (-n) % q.block
    xp = np.pad(x, (0, pad)).reshape(-1, q.block)
    dp = np.pad(d, (0, pad)).reshape(-1, q.block)
    err = np.abs(xp - dp)
    assert (err <= scales[:, None] / 2 + 1e-7).all()
