"""Peer-memory replication tier tests (Checkmate-style).

Covers the subsystem's acceptance criteria:
  * wire framing round-trips and rejects corruption (checksum) and
    protocol damage (magic/length) as distinct, retryable errors
  * replica placement is failure-domain diverse and deterministic
  * replication is asynchronous with ack tracking, bounded in-flight
    window, and exponential-backoff retry under injected faults
  * the socket transport serves real framed requests and surfaces a
    killed peer as unreachable
  * killing a host mid-chain recovers bit-identical state on a
    replacement host from a surviving peer (manifest adoption + chain
    replay), and a peer-served stale chain can never shadow a newer
    durable full (source-aware fallback ordering)
  * the maintenance service prunes peer replicas that are no longer in
    any live chain
"""
import shutil

import numpy as np
import pytest

from repro.checkpoint import (ChecksumError, StoreConfig, TierSpec,
                              order_fulls)
from repro.checkpoint import io as cio
from repro.checkpoint.backends import LocalFSBackend
from repro.checkpoint.peer import (ACK, DATA, GET, MISS, PUT,
                                   LoopbackTransport, PeerGroup, PeerHub,
                                   PeerNode, PeerProtocolError,
                                   PeerReplicaBackend, PeerServer,
                                   PeerUnreachableError, SocketTransport,
                                   decode_message, encode_message, get_hub,
                                   reset_hub)
from repro.checkpoint.remote import FaultInjector, RetryExhaustedError
from repro.core.recovery import load_latest_chain
from repro.maintenance import MaintenanceService


def payload(seed, n=256):
    rng = np.random.default_rng(seed)
    return {"w": rng.standard_normal(n).astype(np.float32),
            "b": rng.standard_normal(4).astype(np.float32)}


def tree_equal(a, b):
    ka, kb = sorted(a), sorted(b)
    return ka == kb and all(np.array_equal(np.asarray(a[k]),
                                           np.asarray(b[k])) for k in ka)


# ----------------------------------------------------------------------
# wire framing
# ----------------------------------------------------------------------

def test_message_roundtrip():
    wire = encode_message(PUT, "full_00000001", {"src": "h0"}, b"\x01\x02")
    kind, key, meta, body = decode_message(wire)
    assert (kind, key, meta, body) == (PUT, "full_00000001",
                                       {"src": "h0"}, b"\x01\x02")


def test_message_checksum_corruption_detected():
    wire = bytearray(encode_message(PUT, "k", {}, b"payload"))
    wire[-10] ^= 0xFF            # damage inside the digest trailer
    with pytest.raises(ChecksumError):
        decode_message(bytes(wire))
    wire2 = bytearray(encode_message(PUT, "k", {}, b"payload"))
    wire2[len(wire2) // 2] ^= 0xFF   # damage inside the body
    with pytest.raises(ChecksumError):
        decode_message(bytes(wire2))


def test_message_protocol_damage_detected():
    with pytest.raises(PeerProtocolError):
        decode_message(b"short")
    wire = encode_message(PUT, "k", {}, b"p")
    with pytest.raises(PeerProtocolError):
        decode_message(b"XXXXXXXX" + wire[8:])      # bad magic
    with pytest.raises(PeerProtocolError):
        decode_message(wire + b"extra")             # length mismatch


# ----------------------------------------------------------------------
# placement
# ----------------------------------------------------------------------

def test_peer_selection_prefers_foreign_domains():
    hub = PeerHub("sel")
    for nid, dom in (("a", "dA"), ("b", "dB"), ("c", "dA"),
                     ("d", "dC"), ("e", "dB")):
        hub.ensure(nid, dom)
    group = PeerGroup("a", "dA", hub=hub)
    # one per foreign domain first, deterministic order
    assert group.select(2) == ["b", "d"]
    # own-domain peers only after every foreign domain is covered
    assert "c" in group.select(4)
    # best-effort when asking for more peers than exist
    assert len(group.select(10)) == 4


def test_peer_selection_is_deterministic():
    hub = PeerHub("det")
    for nid in ("n3", "n1", "n2"):
        hub.ensure(nid, "dX")
    group = PeerGroup("n1", "dX", hub=hub)
    assert group.select(2) == group.select(2) == ["n2", "n3"]


# ----------------------------------------------------------------------
# loopback replication: acks, retries, faults
# ----------------------------------------------------------------------

def make_peer_backend(tmp_path, *, replicas=2, faults=None, hubname="t",
                      zero_copy=False, window=8, max_retries=3):
    hub = PeerHub(hubname)
    hub.ensure("self", "d0")
    hub.ensure("p1", "d1")
    hub.ensure("p2", "d2")
    transport = LoopbackTransport(hub, faults=faults, zero_copy=zero_copy)
    group = PeerGroup("self", "d0", hub=hub)
    lower = LocalFSBackend(str(tmp_path / "lower"))
    be = PeerReplicaBackend(lower, transport, group, replicas=replicas,
                            window=window, max_retries=max_retries,
                            backoff_s=0.001, backoff_max_s=0.01)
    return be, hub


@pytest.mark.parametrize("zero_copy", [False, True])
def test_put_replicates_to_k_peers(tmp_path, zero_copy):
    be, hub = make_peer_backend(tmp_path, zero_copy=zero_copy)
    obj = payload(1)
    be.put("full_00000001", obj)
    be.flush()
    assert be.ack_count("full_00000001") == 2
    for nid in ("p1", "p2"):
        cat = hub.node(nid).catalog()
        assert "full_00000001" in cat
        assert cat["full_00000001"]["src"] == "self"
    assert be.unreplicated_keys() == []
    be.close()


@pytest.mark.parametrize("zero_copy", [False, True])
def test_get_falls_back_to_peer_after_local_loss(tmp_path, zero_copy):
    be, _ = make_peer_backend(tmp_path, zero_copy=zero_copy)
    obj = payload(2)
    be.put("diff_00000003", obj)
    be.flush()
    be.lower.delete("diff_00000003")     # simulate local data loss
    got = be.get("diff_00000003")
    assert tree_equal(got, obj)
    assert be.stats()["peer_reads"] == 1
    be.close()


def test_transient_fault_is_retried(tmp_path):
    faults = FaultInjector(drop_puts=1)
    be, _ = make_peer_backend(tmp_path, faults=faults)
    be.put("full_00000001", payload(3))
    be.flush()
    st = be.stats()
    assert st["retries"] >= 1
    assert st["replication_failures"] == 0
    assert be.ack_count("full_00000001") == 2
    be.close()


def test_dead_peers_count_as_failures_not_errors(tmp_path):
    be, hub = make_peer_backend(tmp_path)
    hub.node("p1").kill()
    hub.node("p2").kill()
    be.put("full_00000001", payload(4))   # must not raise
    be.flush()
    st = be.stats()
    assert st["replication_failures"] == 2
    assert be.ack_count("full_00000001") == 0
    assert be.unreplicated_keys() == ["full_00000001"]
    be.close()


def test_inline_zero_copy_failure_falls_back_to_async_retry(tmp_path):
    faults = FaultInjector(drop_puts=1)
    be, _ = make_peer_backend(tmp_path, faults=faults, zero_copy=True)
    be.put("full_00000001", payload(5))
    be.flush()
    st = be.stats()
    assert st["replication_failures"] == 0
    assert be.ack_count("full_00000001") == 2
    be.close()


def test_patch_forwarded_to_peer_replicas(tmp_path):
    be, hub = make_peer_backend(tmp_path)
    obj = payload(6)
    be.put("full_00000001", obj)
    be.flush()
    new_w = np.full_like(obj["w"], 7.5)
    # frame payload names follow pack order: dict {"b","w"} -> b=a0, w=a1
    tree, arrays = cio.pack(obj)
    idx = [i for i, a in enumerate(arrays) if a.shape == obj["w"].shape][0]
    be.patch("full_00000001", {f"a{idx}": new_w})
    be.flush()
    got = be.get("full_00000001")
    assert np.array_equal(np.asarray(got["w"]), new_w)
    be.lower.delete("full_00000001")
    from_peer = be.get("full_00000001")
    assert np.array_equal(np.asarray(from_peer["w"]), new_w)
    be.close()


def test_delete_broadcast_prunes_replicas(tmp_path):
    be, hub = make_peer_backend(tmp_path)
    be.put("diff_00000001", payload(7))
    be.flush()
    be.delete("diff_00000001")
    be.flush()
    for nid in ("p1", "p2"):
        assert "diff_00000001" not in hub.node(nid).catalog()
    assert be.ack_count("diff_00000001") == 0
    be.close()


# ----------------------------------------------------------------------
# socket transport
# ----------------------------------------------------------------------

def test_socket_transport_roundtrip():
    node = PeerNode("srv", "d1")
    server = PeerServer(node)
    try:
        transport = SocketTransport({"srv": server.address}, timeout_s=5.0)
        obj = payload(8)
        blob = cio.frame_dumps(obj)
        rk, _, rmeta, _ = transport.request(
            "srv", PUT, "full_00000001",
            {"src": "h0", "nbytes": len(blob)}, blob)
        assert rk == ACK and rmeta["node"] == "srv"
        rk, _, _, body = transport.request("srv", GET, "full_00000001",
                                           {"src": "h0"}, b"")
        assert rk == DATA
        assert tree_equal(cio.frame_loads(body), obj)
        rk, _, _, _ = transport.request("srv", GET, "missing",
                                        {"src": "h0"}, b"")
        assert rk == MISS
        transport.close()
    finally:
        server.close()


def test_socket_transport_killed_peer_unreachable():
    node = PeerNode("srv", "d1")
    server = PeerServer(node)
    try:
        transport = SocketTransport({"srv": server.address}, timeout_s=2.0)
        node.kill()
        with pytest.raises(PeerUnreachableError):
            transport.request("srv", PUT, "k", {"src": "h0"}, b"x")
        with pytest.raises(PeerUnreachableError):
            transport.request("unknown", PUT, "k", {"src": "h0"}, b"x")
        transport.close()
    finally:
        server.close()


# ----------------------------------------------------------------------
# host failure -> recovery from a surviving peer
# ----------------------------------------------------------------------

def peer_store(root, hubname, node, *, replicas=2):
    return StoreConfig(str(root), tiers=[
        TierSpec("peer", replicas=replicas, hub=hubname, node_id=node,
                 domain=f"dom_{node}", simulate_peers=True),
        TierSpec("local"),
    ], host_id=node).build()


def test_kill_host_mid_chain_recovers_bit_identical_from_peer(tmp_path):
    reset_hub("crash1")
    store = peer_store(tmp_path / "a", "crash1", "hostA")
    store.save_full(0, payload(10))
    for step in range(1, 6):
        store.save_diff(step, payload(100 + step))
    store.backend.flush()
    control_state, control_diffs = load_latest_chain(store)

    # host A dies: process gone, local storage gone, node out of the hub
    store.close()
    get_hub("crash1").remove("hostA")
    shutil.rmtree(tmp_path / "a")

    # replacement host joins the hub with an empty store and adopts the
    # dead host's manifest from the surviving peers
    store2 = peer_store(tmp_path / "b", "crash1", "hostB")
    adopted = store2.adopt_peer_manifest()
    assert adopted == 6
    state, diffs = load_latest_chain(store2)
    assert tree_equal(state, control_state)
    assert [s for s, _ in diffs] == [s for s, _ in control_diffs]
    for (_, got), (_, want) in zip(diffs, control_diffs):
        assert tree_equal(got, want)
    # adopted entries are provenance-tagged as peer-served
    assert all(e.get("tier") == "peer"
               for e in store2.manifest["fulls"] + store2.manifest["diffs"])
    store2.close()


def test_journal_records_replicated_and_deduped_across_peers(tmp_path):
    reset_hub("crash2")
    store = peer_store(tmp_path / "a", "crash2", "hostA")
    store.save_full(0, payload(11))
    store.save_diff(1, payload(12))
    store.backend.flush()
    manifest = store.backend.peer_manifest()
    # records collected from BOTH replicas but deduped by (src, rseq)
    assert len(manifest) == 2
    assert [r["op"] for _, _, r in manifest] == ["add", "add"]
    assert all(src == "hostA" for src, _, _ in manifest)
    store.close()


def test_adoption_never_shadows_newer_durable_full(tmp_path):
    """A stale peer-served chain must lose to a newer durable full."""
    reset_hub("crash3")
    # host A replicates a chain whose newest full is step 2
    store_a = peer_store(tmp_path / "a", "crash3", "hostA")
    store_a.save_full(2, payload(20))
    store_a.backend.flush()
    store_a.close()
    get_hub("crash3").remove("hostA")

    # host B already has a DURABLE full representing newer state
    store_b = peer_store(tmp_path / "b", "crash3", "hostB")
    newer = payload(21)
    store_b.save_full(1, newer)     # lower nominal step ...
    store_b.manifest["fulls"][-1]["state_step"] = 9  # ... newer state
    adopted = store_b.adopt_peer_manifest()
    assert adopted >= 1             # the foreign entry IS adopted ...
    state, diffs = load_latest_chain(store_b)
    assert tree_equal(state, newer)  # ... but cannot shadow the durable
    store_b.close()


def test_order_fulls_ranks_state_then_step_then_durability():
    durable = {"step": 1, "state_step": 9, "path": "full_a.ckpt"}
    peer = {"step": 2, "state_step": 2, "path": "full_b.ckpt",
            "tier": "peer"}
    tie_peer = {"step": 3, "state_step": 9, "path": "full_c.ckpt",
                "tier": "peer"}
    # highest state wins regardless of nominal step or tier
    assert order_fulls([peer, durable])[0] is durable
    # on a state tie at the same step... different steps: higher step
    assert order_fulls([durable, tie_peer])[0] is tie_peer
    # exact tie on (state_step, step): durable (untagged) outranks peer
    dup = {"step": 3, "state_step": 9, "path": "full_d.ckpt"}
    assert order_fulls([tie_peer, dup])[0] is dup


# ----------------------------------------------------------------------
# maintenance integration
# ----------------------------------------------------------------------

def test_maintenance_prunes_folded_peer_replicas(tmp_path):
    reset_hub("prune1")
    store = peer_store(tmp_path / "a", "prune1", "hostA")
    svc = MaintenanceService(store, gc_slice=8)
    store.attach_maintenance(svc)
    svc.start()
    store.save_full(0, payload(30))
    for step in range(1, 4):
        store.save_diff(step, payload(30 + step))
    store.backend.flush()
    assert len(store.backend.peer_catalog()) == 4
    # GC to one retained chain: the old differentials leave the
    # manifest, and the peer-prune pass drops their replicas too
    store.save_full(4, payload(34))
    store.backend.flush()
    svc.request_gc(1)
    svc.drain(30.0)
    live = {key for _, key in store.scrub_targets()}
    assert set(store.backend.peer_catalog()) == live
    assert svc.stats()["peer_prune_runs"] >= 1
    store.close()


def test_peer_prune_keeps_live_chain(tmp_path):
    reset_hub("prune2")
    store = peer_store(tmp_path / "a", "prune2", "hostA")
    store.save_full(0, payload(40))
    store.save_diff(1, payload(41))
    store.backend.flush()
    # nothing is dead: pruning must delete nothing
    pruned = store.backend.prune_replicas(
        {key for _, key in store.scrub_targets()})
    assert pruned == 0
    assert len(store.backend.peer_catalog()) == 2
    store.close()
